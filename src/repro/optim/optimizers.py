"""Optimizers as pure (init, update) pairs over pytrees.

No optax dependency — implemented here as system code. AdamW for the
transformer trunks, Adagrad for recsys embedding tables (the production
standard: per-row adaptive rates tolerate the power-law id
distribution), SGD+momentum for GNN baselines.

All states are pytrees that shard exactly like their parameters
(the SPMD partitioner propagates the param sharding through the
elementwise update ops), so optimizer state never changes the
distribution story.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, Array], Tuple[PyTree, PyTree]]
    # update(grads, state, params, step) -> (updates, new_state)


def _tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), tree)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw(
    lr: Callable[[Array], Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    max_grad_norm: Optional[float] = 1.0,
    shard_fn: Optional[Callable[[PyTree], PyTree]] = None,
) -> Optimizer:
    """``shard_fn``: optional sharding constraint (ZeRO specs) applied
    to the fp32 inputs of the update math so every fp32 temp (mhat,
    vhat, delta) lives at the optimizer sharding, not the param
    sharding — without it XLA tends to compute the update at the
    (coarser) param sharding and each temp costs a full param-sized
    fp32 buffer per model shard."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return {
            "mu": _tree_zeros_like(params),
            "nu": _tree_zeros_like(params),
        }

    def update(grads, state, params, step):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        p32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        if shard_fn is not None:
            g32 = shard_fn(g32)
            p32 = shard_fn(p32)

        def upd(g, m, v, p):
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            delta = delta + weight_decay * p
            return (-lr_t * delta), m2, v2

        out = jax.tree.map(upd, g32, state["mu"], state["nu"], p32)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu}

    return Optimizer(init, update)


def adagrad(
    lr: Callable[[Array], Array] | float,
    *,
    eps: float = 1e-10,
    initial_accumulator: float = 0.1,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return {
            "acc": jax.tree.map(
                lambda p: jnp.full_like(
                    p, initial_accumulator, dtype=jnp.float32),
                params)
        }

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, a, p):
            g = g.astype(jnp.float32)
            a2 = a + g * g
            return (-lr_t * g / (jnp.sqrt(a2) + eps)).astype(p.dtype), a2

        out = jax.tree.map(upd, grads, state["acc"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        acc = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"acc": acc}

    return Optimizer(init, update)


def sgd_momentum(
    lr: Callable[[Array], Array] | float,
    *,
    momentum: float = 0.9,
    nesterov: bool = False,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return {"v": _tree_zeros_like(params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            v2 = momentum * v + g
            d = g + momentum * v2 if nesterov else v2
            return (-lr_t * d).astype(p.dtype), v2

        out = jax.tree.map(upd, grads, state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"v": v}

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
