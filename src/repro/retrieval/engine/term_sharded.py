"""Term-partitioned (vocab-sharded) inverted index (DESIGN.md §9).

The doc-sharded index (``sharded_index.py``) splits *documents*;
every shard still holds the full ``O(V)`` term directory and the
posting lists of every term that its doc range activates. In the
paper's multilingual regime (|V| ~ 250k) the scaling pressure is the
other way around: a handful of high-DF terms own posting arrays that
outgrow one device's HBM no matter how few docs a shard holds, and
the replicated term directory stops being a rounding error. The
standard answer (GPUSparse-style parallel inverted files) is to
partition by **vocabulary range**: shard ``s`` owns the *complete*
posting lists of terms ``[lo_s, hi_s)`` and nothing else.

That flips the merge algebra. Under doc sharding a document's whole
score lives on one shard, so the merge is ``all_gather`` of per-shard
top-k + re-top-k. Under term sharding one document's score is spread
across every shard its terms land on, so per-shard results are
**partial sums** over the full doc space that must be added — a
``psum``/all-reduce of the ``(B, N)`` partials inside the
``shard_map`` body — before a single global top-k. Per-shard top-k
would be meaningless here.

Layout (stacked on a leading shard axis, padded to the widest shard):

    term_starts (S, Vloc) i32     postings_doc (S, Pmax) i32 (GLOBAL)
    term_lens   (S, Vloc) i32     postings_val (S, Pmax) f32
    term_ubs    (S, Vloc) f32     shard_lo/shard_hi (S,) i32

``Vloc = max(hi_s - lo_s)`` and term ids are remapped per shard
(``local = global - lo_s``, built via ``build_inverted_index(...,
vocab_range=)``). Queries are *routed*: each shard masks the query's
active terms to its range (value 0 elsewhere), so padded slots and
out-of-range terms contribute exactly 0 to the partial sums.

Pruning composes per shard: tier 1 sums each shard's *ceiling*
partials (from that shard's local upper bounds) into a global
MaxScore bound, tier 2 rescores the surviving candidates exactly
from forward rows stored ONCE on the index (forward rows carry
global term ids, so they are replicated — the memory win of term
sharding is the posting arrays, which dominate).

Two execution paths with identical semantics, mirroring the
doc-sharded index: ``mesh`` given — ``shard_map`` + ``psum``;
``mesh=None`` — a jitted ``vmap`` + sum on one device.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.engine.sharded_index import (resolve_shard_axis,
                                                  shard_mapped)
from repro.retrieval.index import InvertedIndex, build_inverted_index
from repro.retrieval.sparse_rep import SparseRep

Array = jax.Array

# placement moved to the ShardPlan planner (DESIGN.md §14);
# choose_shard_axis survives here as the deprecated string shim
from repro.retrieval.engine.shard2d import (  # noqa: E402,F401
    DIR_BYTES_PER_TERM, choose_shard_axis, mass_balanced_boundaries)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TermShardedIndex:
    term_starts: Array      # (S, Vloc) i32 — local term offsets
    term_lens: Array        # (S, Vloc) i32
    postings_doc: Array     # (S, Pmax) i32 — GLOBAL doc ids
    postings_val: Array     # (S, Pmax) f32
    term_ubs: Array         # (S, Vloc) f32 — per-shard upper bounds
    shard_lo: Array         # (S,) i32 — vocab range starts
    shard_hi: Array         # (S,) i32 — vocab range ends (exclusive)
    n_shards: int           # static
    n_docs: int             # static — every shard scores all docs
    vocab_size: int         # static — global V
    local_vocab: int        # static — padded per-shard vocab width
    max_postings: int       # static — longest list over all shards
    boundaries: Tuple[int, ...] = ()      # static — the vocab cuts
    doc_values: Optional[Array] = None    # (N, K) f32 — forward rows,
    doc_indices: Optional[Array] = None   # (N, K) i32 — stored once

    def tree_flatten(self):
        children = (self.term_starts, self.term_lens,
                    self.postings_doc, self.postings_val,
                    self.term_ubs, self.shard_lo, self.shard_hi,
                    self.doc_values, self.doc_indices)
        aux = (self.n_shards, self.n_docs, self.vocab_size,
               self.local_vocab, self.max_postings, self.boundaries)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children[:7], *aux, doc_values=children[7],
                   doc_indices=children[8])

    @property
    def has_forward(self) -> bool:
        return self.doc_values is not None and self.doc_indices is not None

    def memory_bytes(self) -> int:
        arrays = [self.term_starts, self.term_lens, self.postings_doc,
                  self.postings_val, self.term_ubs, self.shard_lo,
                  self.shard_hi]
        for opt in (self.doc_values, self.doc_indices):
            if opt is not None:
                arrays.append(opt)
        return int(sum(np.asarray(a).nbytes for a in arrays))

    def stats(self) -> Dict[str, float]:
        return {
            "n_shards": self.n_shards,
            "n_docs": self.n_docs,
            "vocab_size": self.vocab_size,
            "local_vocab": self.local_vocab,
            "max_postings": self.max_postings,
            "memory_bytes": self.memory_bytes(),
        }


def term_shard_index(reps: SparseRep, vocab_size: int, n_shards: int,
                     *, boundaries: Optional[Sequence[int]] = None,
                     balance: str = "mass",
                     keep_forward: bool = False) -> TermShardedIndex:
    """Build per-shard indexes over contiguous vocab ranges (host-side).

    The vocabulary is cut at ``boundaries``; by default the cuts are
    balanced by cumulative posting *mass* (``balance="mass"`` —
    ``shard2d.mass_balanced_boundaries``), so a stopword-heavy term
    cannot drag every shard's padded posting array out to its own
    range's length. ``balance="width"`` restores the even
    ``ceil(V / n_shards)`` ranges. Each range is indexed independently
    via ``build_inverted_index(vocab_range=...)`` — remapped local
    term ids, *global* doc ids — and the CSC arrays are padded to the
    widest shard. A shard whose range holds no active terms packs the
    usual length-1 zero postings and contributes 0.

    ``keep_forward=True`` stores the (N, K) forward rows once on the
    index (not per shard — they carry global term ids), enabling the
    two-tier pruned path.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > vocab_size:
        raise ValueError(
            f"n_shards={n_shards} exceeds vocab size {vocab_size}")
    if balance not in ("mass", "width"):
        raise ValueError(
            f"balance must be 'mass' or 'width', got {balance!r}")

    from repro.retrieval.sparse_rep import device_get

    host = device_get(reps) if isinstance(reps.values, jax.Array) else reps
    k = host.width
    v = np.asarray(host.values, np.float32).reshape(-1, k)
    i = np.asarray(host.indices, np.int32).reshape(-1, k)
    n = np.asarray(host.nnz, np.int32).reshape(-1)
    rep = SparseRep(v, i, n)

    if boundaries is None:
        if balance == "mass":
            counts = np.bincount(i[v > 0].ravel(), minlength=vocab_size)
            boundaries = mass_balanced_boundaries(counts, n_shards)
        else:
            # even width, strictly increasing for any V >= n_shards
            boundaries = [s * vocab_size // n_shards
                          for s in range(n_shards + 1)]
    boundaries = [int(b) for b in boundaries]
    if (len(boundaries) != n_shards + 1 or boundaries[0] != 0
            or boundaries[-1] != vocab_size
            or any(a >= b for a, b in zip(boundaries, boundaries[1:]))):
        raise ValueError(
            f"boundaries must be {n_shards + 1} strictly increasing "
            f"cuts from 0 to {vocab_size}, got {boundaries}")

    parts = []
    for s in range(n_shards):
        lo, hi = boundaries[s], boundaries[s + 1]
        parts.append(build_inverted_index(
            rep, vocab_size, vocab_range=(lo, hi),
            stopword_warn_frac=1.1))

    v_loc = max(p.vocab_size for p in parts)
    p_max = max(p.n_postings for p in parts)
    starts = np.zeros((n_shards, v_loc), np.int32)
    lens = np.zeros((n_shards, v_loc), np.int32)
    ubs = np.zeros((n_shards, v_loc), np.float32)
    pdoc = np.zeros((n_shards, p_max), np.int32)
    pval = np.zeros((n_shards, p_max), np.float32)
    for s, p in enumerate(parts):
        starts[s, :p.vocab_size] = np.asarray(p.term_starts)
        lens[s, :p.vocab_size] = np.asarray(p.term_lens)
        ubs[s, :p.vocab_size] = np.asarray(p.term_ubs)
        pdoc[s, :p.n_postings] = np.asarray(p.postings_doc)
        pval[s, :p.n_postings] = np.asarray(p.postings_val)

    return TermShardedIndex(
        term_starts=jnp.asarray(starts),
        term_lens=jnp.asarray(lens),
        postings_doc=jnp.asarray(pdoc),
        postings_val=jnp.asarray(pval),
        term_ubs=jnp.asarray(ubs),
        shard_lo=jnp.asarray(boundaries[:-1], dtype=jnp.int32),
        shard_hi=jnp.asarray(boundaries[1:], dtype=jnp.int32),
        n_shards=n_shards,
        n_docs=v.shape[0],
        vocab_size=vocab_size,
        local_vocab=v_loc,
        max_postings=max(p.max_postings for p in parts),
        boundaries=tuple(boundaries),
        doc_values=jnp.asarray(v) if keep_forward else None,
        doc_indices=jnp.asarray(i) if keep_forward else None,
    )


def _route(qv: Array, qi: Array, lo: Array, hi: Array, local_vocab: int
           ) -> Tuple[Array, Array]:
    """Mask the query's active terms to one shard's vocab range and
    remap them to local ids; everything else carries value 0 (and so
    contributes exactly 0 to the partial sums)."""
    in_shard = (qi >= lo) & (qi < hi)
    lqv = jnp.where(in_shard, qv, 0.0)
    lqi = jnp.clip(qi - lo, 0, local_vocab - 1)
    return lqv, lqi


def _local_index(st: Array, ln: Array, pd: Array, pv: Array,
                 index: TermShardedIndex, ubs: Optional[Array] = None
                 ) -> InvertedIndex:
    return InvertedIndex(
        term_starts=st, term_lens=ln, postings_doc=pd, postings_val=pv,
        n_docs=index.n_docs, vocab_size=index.local_vocab,
        max_postings=index.max_postings, term_ubs=ubs)


def _partial_scores(qv: Array, qi: Array, st: Array, ln: Array,
                    pd: Array, pv: Array, lo: Array, hi: Array,
                    index: TermShardedIndex) -> Array:
    """(B, n_docs) PARTIAL scores of one shard — the contribution of
    this shard's vocab range to every document's total."""
    from repro.retrieval.score import impact_scores

    lqv, lqi = _route(qv, qi, lo, hi, index.local_vocab)
    rep = SparseRep(lqv, lqi,
                    jnp.sum((lqv > 0).astype(jnp.int32), axis=-1))
    return impact_scores(rep, _local_index(st, ln, pd, pv, index))


def _partial_ub_scores(qv: Array, qi: Array, st: Array, ln: Array,
                       pd: Array, pv: Array, ubs: Array, lo: Array,
                       hi: Array, index: TermShardedIndex) -> Array:
    """(B, n_docs) partial MaxScore ceilings from this shard's local
    upper bounds (gathers only postings_doc, like tier 1 unsharded)."""
    from repro.retrieval.engine.pruning import upper_bound_scores

    lqv, lqi = _route(qv, qi, lo, hi, index.local_vocab)
    rep = SparseRep(lqv, lqi,
                    jnp.sum((lqv > 0).astype(jnp.int32), axis=-1))
    return upper_bound_scores(rep,
                              _local_index(st, ln, pd, pv, index, ubs))


@functools.partial(jax.jit, static_argnames=("k",))
def _vmap_retrieve(qv: Array, qi: Array, index: TermShardedIndex,
                   k: int) -> Tuple[Array, Array]:
    """Single-device path: per-shard partials under one jitted vmap,
    summed (the term-sharded merge algebra), then one global top-k."""
    partials = jax.vmap(
        lambda st, ln, pd, pv, lo, hi: _partial_scores(
            qv, qi, st, ln, pd, pv, lo, hi, index)
    )(index.term_starts, index.term_lens, index.postings_doc,
      index.postings_val, index.shard_lo, index.shard_hi)  # (S, B, N)
    vals, idx = jax.lax.top_k(jnp.sum(partials, axis=0), k)
    return vals, idx.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("k", "candidates"))
def _vmap_pruned_retrieve(queries: SparseRep, index: TermShardedIndex,
                          k: int, candidates: int, prune_margin: Array
                          ) -> Tuple[Array, Array, Array]:
    from repro.retrieval.engine.pruning import select_and_rescore

    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    ub_partials = jax.vmap(
        lambda st, ln, pd, pv, ubs, lo, hi: _partial_ub_scores(
            qv, qi, st, ln, pd, pv, ubs, lo, hi, index)
    )(index.term_starts, index.term_lens, index.postings_doc,
      index.postings_val, index.term_ubs, index.shard_lo,
      index.shard_hi)                                      # (S, B, N)
    ub = jnp.sum(ub_partials, axis=0)
    return select_and_rescore(ub, queries, index.doc_values,
                              index.doc_indices, index.vocab_size,
                              k, candidates, prune_margin)


def term_sharded_retrieve(
    queries: SparseRep,
    index: TermShardedIndex,
    k: int = 10,
    *,
    mesh=None,
    axis_name: Optional[str] = None,
    prune_margin: Optional[float] = None,
    candidates: Optional[int] = None,
) -> Tuple[Array, Array]:
    """Top-k over the term-sharded index; ids are global doc ids.

    Exact by default: per-shard partial impact sums are all-reduced
    (``psum`` under a mesh, a plain sum under the single-device vmap
    fallback) and a single global top-k follows — id parity with the
    unsharded impact scorer is pinned by tests. With ``prune_margin``
    set, the two-tier MaxScore composition runs instead: per-shard
    *ceiling* partials (each from its own shard's upper bounds) are
    all-reduced into the global bound, and the surviving candidates
    are rescored exactly from the index's forward rows
    (``keep_forward=True`` at build time).
    """
    k = min(k, index.n_docs)
    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)

    prune = prune_margin is not None
    if prune:
        if not index.has_forward:
            raise ValueError(
                "term_sharded_retrieve: pruning needs forward rows — "
                "build with term_shard_index(..., keep_forward=True)")
        if not 0.0 <= prune_margin <= 1.0:
            raise ValueError(f"prune_margin must be in [0, 1], got "
                             f"{prune_margin}")
        if candidates is None:
            # the baseline planner budget; the skew-aware doubling of
            # engine.pruning.default_candidates needs posting-length
            # percentiles, which the stacked shards don't carry
            candidates = max(4 * k, 64)
        candidates = min(max(candidates, k), index.n_docs)
        margin = jnp.float32(prune_margin)

    if mesh is None:
        if prune:
            vals, idx, _ = _vmap_pruned_retrieve(
                queries, index, k, candidates, margin)
            return vals, idx
        return _vmap_retrieve(qv, qi, index, k)

    axis_name = resolve_shard_axis(mesh, axis_name, index.n_shards,
                                   what="term_sharded_retrieve")

    if prune:
        doc_values, doc_indices = index.doc_values, index.doc_indices

        def body(st, ln, pd, pv, ubs, lo, hi):
            from repro.retrieval.engine.pruning import select_and_rescore

            partial = _partial_ub_scores(qv, qi, st[0], ln[0], pd[0],
                                         pv[0], ubs[0], lo[0], hi[0],
                                         index)
            ub = jax.lax.psum(partial, axis_name)      # global ceilings
            rep = SparseRep(qv, qi, jnp.sum((qv > 0).astype(jnp.int32),
                                            axis=-1))
            vals, idx, _ = select_and_rescore(
                ub, rep, doc_values, doc_indices, index.vocab_size,
                k, candidates, margin)
            return vals, idx

        merged = shard_mapped(body, mesh, axis_name, n_in=7)
        vals, idx = merged(index.term_starts, index.term_lens,
                           index.postings_doc, index.postings_val,
                           index.term_ubs, index.shard_lo,
                           index.shard_hi)
        return vals, idx.astype(jnp.int32)

    def body(st, ln, pd, pv, lo, hi):
        partial = _partial_scores(qv, qi, st[0], ln[0], pd[0], pv[0],
                                  lo[0], hi[0], index)  # (B, N)
        total = jax.lax.psum(partial, axis_name)        # sum-merge
        vals, idx = jax.lax.top_k(total, k)
        return vals, idx

    merged = shard_mapped(body, mesh, axis_name, n_in=6)
    vals, idx = merged(index.term_starts, index.term_lens,
                       index.postings_doc, index.postings_val,
                       index.shard_lo, index.shard_hi)
    return vals, idx.astype(jnp.int32)
