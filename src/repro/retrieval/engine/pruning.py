"""MaxScore/WAND-style two-tier pruned retrieval (DESIGN.md §8.1).

The exact impact scorer walks *every* posting of every active query
term at full f32 width. Classic dynamic pruning (WAND, MaxScore)
observes that a per-term score ceiling — ``ub[t] = max impact in
t's posting list`` — bounds any document's score long before the
exact sum is known, so most documents never need exact scoring.

The TPU/JAX adaptation keeps static shapes by splitting retrieval
into two fixed-size tiers instead of a dynamic pointer walk:

* **Tier 1 (upper-bound pass, cheap).** For each query, score every
  document with the *ceiling* contribution ``c[t] = q[t] * ub[t]``
  instead of the real posting impact:

      ub_score[d] = sum_{t active in q} c[t] * [d in postings(t)]

  This walks the same posting windows as the exact scorer but gathers
  only ``postings_doc`` (the i32 ids) — the f32 ``postings_val``
  stream, half the gather traffic, is never touched. Because impacts
  are non-negative, ``ub_score[d] >= score[d]`` for every doc.

* **Tier 2 (exact rescoring, narrow).** The top ``C`` docs by upper
  bound become candidates; only they are scored exactly, from the
  index's *forward* rows (``doc_values``/``doc_indices``): scatter the
  query into a dense (V,) vector once, then each candidate costs one
  (K,) gather + dot — O(C*K) per query instead of O(Q*Lmax).

Safety: a true top-k doc can only be missed if its upper bound fell
below the candidate cutoff. The pass therefore also reports, per
query, whether the pruning was *provably exact*: every excluded doc's
ceiling is <= the exact k-th best candidate score. With the default
margin (0.0) and a candidate budget comfortably above k this holds in
practice and the ids are identical to ``method="impact"`` — the
parity is pinned by tests. ``prune_margin`` trades that guarantee for
speed: candidates whose *ceiling* cannot reach ``prune_margin`` times
the k-th best ceiling are dropped before rescoring (0 = keep all, 1 =
only docs whose ceiling reaches the k-th best ceiling).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.retrieval.index import InvertedIndex
from repro.retrieval.sparse_rep import SparseRep
from repro.sparse.segment import segment_sum

Array = jax.Array
NEG_INF = -1e30


def default_candidates(index: InvertedIndex, k: int) -> int:
    """Candidate budget for tier 2 — the engine's pruning planner.

    Baseline: ``max(4k, 64)``, clamped to the corpus. When the
    posting-length percentiles on the index show stopword-like skew
    (p99 >= 8 * p50), upper bounds are loose for the skewed terms and
    the ceiling ranking is less selective — double the budget.
    """
    base = max(4 * k, 64)
    pct = index.posting_percentiles
    if len(pct) == 4 and pct[0] > 0 and pct[2] >= 8 * pct[0]:
        base *= 2
    return min(max(base, k), index.n_docs)


def upper_bound_scores(queries: SparseRep, index: InvertedIndex) -> Array:
    """Tier-1 ceilings: dense ``(B, n_docs)`` of per-doc upper bounds.

    Same padded-window walk as ``score.impact_scores`` but the lane
    weight is the *term ceiling* ``q[t] * ub[t]`` — ``postings_val``
    is never gathered.
    """
    if index.term_ubs is None:
        raise ValueError(
            "upper_bound_scores: index has no term_ubs — rebuild with "
            "build_inverted_index(..., with_upper_bounds=True)")
    l_max = index.max_postings
    p_total = index.postings_doc.shape[0]
    lane = jnp.arange(l_max, dtype=jnp.int32)

    def one(qv: Array, qi: Array) -> Array:
        c = qv * index.term_ubs[qi]                        # (Q,)
        starts = index.term_starts[qi]
        lens = index.term_lens[qi]
        pos = starts[:, None] + lane[None, :]              # (Q, Lmax)
        valid = (lane[None, :] < lens[:, None]) & (qv > 0)[:, None]
        pos = jnp.clip(pos, 0, p_total - 1)
        docs = jnp.where(valid, index.postings_doc[pos], 0)
        w = jnp.where(valid, c[:, None], 0.0)
        return segment_sum(w.ravel(), docs.ravel(), index.n_docs)

    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    return jax.vmap(one)(qv, qi)


def select_and_rescore(ub: Array, queries: SparseRep,
                       doc_values: Array, doc_indices: Array,
                       vocab_size: int, k: int, candidates: int,
                       prune_margin: Array
                       ) -> Tuple[Array, Array, Array]:
    """Tier 2 given tier-1 ceilings: candidate selection + exact
    rescoring from forward rows.

    Shared by the single-index pruned path (ceilings from
    ``upper_bound_scores``) and the term-sharded engine (ceilings are
    the sum of per-shard partials — the merge algebra differs, the
    rescoring does not). Returns ``(vals, idx, exact_frontier)``;
    traceable, so it runs inside jit/shard_map bodies.
    """
    n = ub.shape[1]
    c_plus = min(candidates + 1, n)

    # tier 1: top-(C+1) ceilings; the (C+1)-th is the best excluded doc
    ub_top, cand = jax.lax.top_k(ub, c_plus)           # (B, C+1)
    if c_plus > candidates:
        excluded_ub = ub_top[:, -1]                    # (B,)
        ub_top, cand = ub_top[:, :candidates], cand[:, :candidates]
    else:
        excluded_ub = jnp.full(ub.shape[0], NEG_INF)   # nothing excluded

    # margin mask: drop candidates whose ceiling cannot reach
    # prune_margin * (k-th best ceiling)
    theta = ub_top[:, min(k, candidates) - 1]          # (B,)
    keep = ub_top >= prune_margin * theta[:, None]
    excluded_ub = jnp.maximum(
        excluded_ub, jnp.max(jnp.where(keep, NEG_INF, ub_top), axis=1))

    # candidates sorted by doc id so score ties break to the lowest id,
    # matching lax.top_k over the dense (N,) exact scores
    cand_sort = jnp.where(keep, cand, n)
    order = jnp.argsort(cand_sort, axis=1)
    cand_sort = jnp.take_along_axis(cand_sort, order, axis=1)
    keep = cand_sort < n
    cand_safe = jnp.clip(cand_sort, 0, n - 1)

    # tier 2: exact rescoring from the forward rows
    qk = queries.width
    qv = queries.values.reshape(-1, qk).astype(jnp.float32)
    qi = queries.indices.reshape(-1, qk)

    def rescore(qv_row, qi_row, cand_row, keep_row):
        q_dense = jnp.zeros(vocab_size, jnp.float32)
        q_dense = q_dense.at[qi_row].add(
            jnp.where(qv_row > 0, qv_row, 0.0))
        dv = doc_values[cand_row]                      # (C, K)
        di = doc_indices[cand_row]                     # (C, K)
        exact = jnp.sum(q_dense[di] * dv, axis=1)      # (C,)
        return jnp.where(keep_row, exact, NEG_INF)

    exact = jax.vmap(rescore)(qv, qi, cand_safe, keep)     # (B, C)
    # >= k candidates always survive the margin mask (the top-k docs
    # by ceiling satisfy ub >= margin * theta for margin <= 1), so
    # every selected slot holds a rescored survivor
    vals, pos = jax.lax.top_k(exact, k)
    idx = jnp.take_along_axis(cand_safe, pos, axis=1).astype(jnp.int32)

    # provably exact iff every excluded doc's ceiling is <= the exact
    # k-th best candidate score
    exact_frontier = excluded_ub <= vals[:, min(k, vals.shape[1]) - 1]
    return vals, idx, exact_frontier


@functools.partial(jax.jit, static_argnames=("k", "candidates"))
def _pruned_retrieve(queries: SparseRep, index: InvertedIndex, k: int,
                     candidates: int, prune_margin: Array
                     ) -> Tuple[Array, Array, Array]:
    ub = upper_bound_scores(queries, index)            # (B, N)
    return select_and_rescore(ub, queries, index.doc_values,
                              index.doc_indices, index.vocab_size,
                              k, candidates, prune_margin)


def pruned_retrieve(
    queries: SparseRep,
    index: InvertedIndex,
    k: int = 10,
    *,
    prune_margin: float = 0.0,
    candidates: Optional[int] = None,
    with_diagnostics: bool = False,
):
    """Two-tier pruned top-k (see module docstring).

    Returns ``(vals (B, k), idx (B, k))``; with
    ``with_diagnostics=True`` also a ``(B,)`` bool of per-query
    provable exactness (every excluded doc's ceiling <= the exact
    k-th best score).
    """
    if index.term_ubs is None:
        raise ValueError(
            "pruned_retrieve: the index carries no per-term upper "
            "bounds (term_ubs) — rebuild with with_upper_bounds=True")
    if not index.has_forward:
        raise ValueError(
            "pruned_retrieve: the index carries no forward rows for "
            "rescoring — rebuild with keep_forward=True")
    if not 0.0 <= prune_margin <= 1.0:
        raise ValueError(f"prune_margin must be in [0, 1], got "
                         f"{prune_margin}")
    k = min(k, index.n_docs)
    if candidates is None:
        candidates = default_candidates(index, k)
    candidates = min(max(candidates, k), index.n_docs)
    vals, idx, frontier = _pruned_retrieve(
        queries, index, k, candidates, jnp.float32(prune_margin))
    if with_diagnostics:
        return vals, idx, frontier
    return vals, idx
