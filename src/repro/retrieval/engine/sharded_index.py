"""Doc-sharded inverted index over a device mesh (DESIGN.md §8.3).

The single-device index caps the corpus at one HBM's worth of
postings. Sharding the *documents* (not the vocabulary) keeps every
shard a self-contained inverted index over a contiguous doc range —
each device scores its local range with the unchanged impact scorer,
then the per-shard winners are merged with the same all_gather +
re-top-k reduction ``launch/steps.build_retrieval_step`` already uses
for dense candidate sharding. Corpus size scales with device count;
the (B, N) score matrix never exists anywhere.

Layout: the per-shard CSC arrays are stacked on a leading shard axis
(padded to the widest shard) —

    term_starts  (S, V) i32      postings_doc (S, Pmax) i32
    term_lens    (S, V) i32      postings_val (S, Pmax) f32
    shard_counts (S,)   i32      — real docs per shard

Shard ``s`` holds docs ``[s*docs_per_shard, ...)`` in original order,
so ``global id = s * docs_per_shard + local id`` and tie-breaks match
the unsharded scorer exactly (per-shard top-k is stable, shards are
gathered in ascending order).

Two execution paths with identical semantics:

* ``mesh`` given — ``shard_map`` over the shard axis: one shard per
  device, cross-shard merge via ``all_gather``. ``n_shards`` must
  equal the mesh axis size.
* ``mesh=None`` — a ``vmap`` over the shard axis on one device: the
  functional fallback used by tests, CPU benches, and single-device
  serving (sharding is then a partition of work, not of memory).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.index import InvertedIndex, build_inverted_index
from repro.retrieval.sparse_rep import SparseRep

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared mesh plumbing (doc- and term-sharded paths)
# ---------------------------------------------------------------------------

def resolve_mesh_axes(mesh, axis_names, sizes: Tuple[int, ...],
                      what: str = "sharded_retrieve"
                      ) -> Tuple[str, ...]:
    """Default + validate the mesh axes the logical shard dims map
    onto: one shard per device along each axis, so each axis size must
    equal the corresponding shard count. ``axis_names=None`` takes the
    mesh's leading axes in order (the 1D indexes use its first axis,
    the 2D grid its first two)."""
    if axis_names is None:
        if len(mesh.axis_names) < len(sizes):
            raise ValueError(
                f"{what}: mesh has {len(mesh.axis_names)} axes "
                f"{tuple(mesh.axis_names)}, needs {len(sizes)}")
        axis_names = tuple(mesh.axis_names[:len(sizes)])
    else:
        axis_names = tuple(axis_names)
        if len(axis_names) != len(sizes):
            raise ValueError(
                f"{what}: {len(axis_names)} axis names for "
                f"{len(sizes)} shard dims")
    for name, n_shards in zip(axis_names, sizes):
        n_dev = mesh.shape[name]
        if n_dev != n_shards:
            raise ValueError(
                f"{what}: n_shards={n_shards} must equal "
                f"mesh axis {name!r} size {n_dev}")
    return axis_names


def resolve_shard_axis(mesh, axis_name: Optional[str], n_shards: int,
                       what: str = "sharded_retrieve") -> str:
    """1D special case of ``resolve_mesh_axes``: the single mesh axis
    the shard dimension maps onto."""
    names = None if axis_name is None else (axis_name,)
    return resolve_mesh_axes(mesh, names, (n_shards,), what)[0]


def shard_mapped(body, mesh, axis_name: Optional[str], n_in: int,
                 n_out: int = 2, in_specs=None):
    """``compat.shard_map`` wrapper shared by the sharded indexes:
    the first ``n_in`` args are split on ``axis_name`` (one shard per
    device), outputs are replicated. The 2D grid passes explicit
    ``in_specs`` instead (its stacked arrays split on two mesh axes at
    once, its range/chunk arrays on one each). ``check_vma`` is off —
    the post-merge results (all_gather+top_k or psum) ARE replicated
    but the vma/rep tracer cannot prove it, same situation as
    ``build_retrieval_step``."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    if in_specs is None:
        in_specs = tuple(P(axis_name) for _ in range(n_in))
    else:
        in_specs = tuple(in_specs)
        if len(in_specs) != n_in:
            raise ValueError(
                f"shard_mapped: {len(in_specs)} in_specs for "
                f"{n_in} inputs")

    return shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=tuple(P() for _ in range(n_out)),
        check_vma=False,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    term_starts: Array      # (S, V) i32
    term_lens: Array        # (S, V) i32
    postings_doc: Array     # (S, Pmax) i32 — local doc ids
    postings_val: Array     # (S, Pmax) f32
    shard_counts: Array     # (S,) i32 — real docs per shard
    n_shards: int           # static
    docs_per_shard: int     # static — uniform shard stride
    n_docs: int             # static — total real docs
    vocab_size: int         # static
    max_postings: int       # static — longest list over all shards

    def tree_flatten(self):
        children = (self.term_starts, self.term_lens,
                    self.postings_doc, self.postings_val,
                    self.shard_counts)
        aux = (self.n_shards, self.docs_per_shard, self.n_docs,
               self.vocab_size, self.max_postings)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def memory_bytes(self) -> int:
        return int(sum(np.asarray(a).nbytes for a in (
            self.term_starts, self.term_lens,
            self.postings_doc, self.postings_val, self.shard_counts)))

    def stats(self) -> Dict[str, float]:
        return {
            "n_shards": self.n_shards,
            "docs_per_shard": self.docs_per_shard,
            "n_docs": self.n_docs,
            "vocab_size": self.vocab_size,
            "max_postings": self.max_postings,
            "memory_bytes": self.memory_bytes(),
        }


def shard_index(reps: SparseRep, vocab_size: int, n_shards: int
                ) -> ShardedIndex:
    """Build per-shard indexes over contiguous doc chunks (host-side).

    Docs are split into ``n_shards`` contiguous ranges of
    ``ceil(N / n_shards)``; each range is indexed independently with
    local doc ids and the CSC arrays are padded to the widest shard so
    the stacked layout is rectangular.
    """
    from repro.retrieval.sparse_rep import device_get

    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    host = device_get(reps) if isinstance(reps.values, jax.Array) else reps
    k = host.width
    v = np.asarray(host.values, np.float32).reshape(-1, k)
    i = np.asarray(host.indices, np.int32).reshape(-1, k)
    n = np.asarray(host.nnz, np.int32).reshape(-1)
    n_docs = v.shape[0]
    if n_shards > n_docs:
        raise ValueError(
            f"n_shards={n_shards} exceeds corpus size {n_docs}")
    dps = -(-n_docs // n_shards)

    parts = []
    for s in range(n_shards):
        lo, hi = s * dps, min((s + 1) * dps, n_docs)
        parts.append(build_inverted_index(
            SparseRep(v[lo:hi], i[lo:hi], n[lo:hi]), vocab_size,
            with_upper_bounds=False, stopword_warn_frac=1.1))

    p_max = max(p.n_postings for p in parts)
    starts = np.stack([np.asarray(p.term_starts) for p in parts])
    lens = np.stack([np.asarray(p.term_lens) for p in parts])
    pdoc = np.zeros((n_shards, p_max), np.int32)
    pval = np.zeros((n_shards, p_max), np.float32)
    for s, p in enumerate(parts):
        pdoc[s, :p.n_postings] = np.asarray(p.postings_doc)
        pval[s, :p.n_postings] = np.asarray(p.postings_val)
    counts = np.asarray(
        [min((s + 1) * dps, n_docs) - s * dps for s in range(n_shards)],
        np.int32)

    return ShardedIndex(
        term_starts=jnp.asarray(starts),
        term_lens=jnp.asarray(lens),
        postings_doc=jnp.asarray(pdoc),
        postings_val=jnp.asarray(pval),
        shard_counts=jnp.asarray(counts),
        n_shards=n_shards,
        docs_per_shard=dps,
        n_docs=n_docs,
        vocab_size=vocab_size,
        max_postings=max(p.max_postings for p in parts),
    )


def _local_scores(qv: Array, qi: Array, starts: Array, lens: Array,
                  pdoc: Array, pval: Array, count: Array,
                  index: ShardedIndex) -> Array:
    """(B, docs_per_shard) exact scores of one shard; padded docs
    (local id >= count) are masked to -inf."""
    from repro.retrieval.score import impact_scores

    local = InvertedIndex(
        term_starts=starts, term_lens=lens,
        postings_doc=pdoc, postings_val=pval,
        n_docs=index.docs_per_shard, vocab_size=index.vocab_size,
        max_postings=index.max_postings)
    scores = impact_scores(SparseRep(qv, qi, jnp.sum(
        (qv > 0).astype(jnp.int32), axis=-1)), local)
    doc_ids = jnp.arange(index.docs_per_shard, dtype=jnp.int32)
    return jnp.where(doc_ids[None, :] < count, scores, NEG_INF)


@functools.partial(jax.jit, static_argnames=("k",))
def _vmap_retrieve(qv: Array, qi: Array, index: ShardedIndex, k: int
                   ) -> Tuple[Array, Array]:
    """Single-device path: all shards scored under one jitted vmap.

    Shard chunks are contiguous, so the flattened (S * dps) position
    of a doc IS its original id — no offset bookkeeping needed."""
    scores = jax.vmap(
        lambda st, ln, pd, pv, ct: _local_scores(
            qv, qi, st, ln, pd, pv, ct, index)
    )(index.term_starts, index.term_lens, index.postings_doc,
      index.postings_val, index.shard_counts)          # (S, B, dps)
    flat = jnp.moveaxis(scores, 0, 1).reshape(qv.shape[0], -1)
    vals, idx = jax.lax.top_k(flat, k)
    return vals, idx.astype(jnp.int32)


def sharded_retrieve(
    queries: SparseRep,
    index: ShardedIndex,
    k: int = 10,
    *,
    mesh=None,
    axis_name: Optional[str] = None,
) -> Tuple[Array, Array]:
    """Top-k over the sharded index; ids are global (original) doc ids.

    With ``mesh`` the shard axis runs under ``shard_map`` (one shard
    per device along ``axis_name``, default: the mesh's first axis);
    without, a single-device ``vmap`` computes the same thing.
    """
    k = min(k, index.n_docs)
    dps = index.docs_per_shard
    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)

    if mesh is None:
        return _vmap_retrieve(qv, qi, index, k)

    axis_name = resolve_shard_axis(mesh, axis_name, index.n_shards)
    kk = min(k, dps)

    def body(st, ln, pd, pv, ct):
        scores = _local_scores(qv, qi, st[0], ln[0], pd[0], pv[0],
                               ct[0], index)           # (B, dps)
        lv, li = jax.lax.top_k(scores, kk)
        li = li + jax.lax.axis_index(axis_name) * dps  # -> global ids
        all_v = jax.lax.all_gather(lv, axis_name, axis=1, tiled=True)
        all_i = jax.lax.all_gather(li, axis_name, axis=1, tiled=True)
        mv, pos = jax.lax.top_k(all_v, k)
        return mv, jnp.take_along_axis(all_i, pos, axis=1)

    merged = shard_mapped(body, mesh, axis_name, n_in=5)
    vals, idx = merged(index.term_starts, index.term_lens,
                       index.postings_doc, index.postings_val,
                       index.shard_counts)
    return vals, idx.astype(jnp.int32)
