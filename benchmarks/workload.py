"""Reusable serving-workload machinery for the traffic benches.

Everything the simulated-clock serving benches share lives here so
``bench_serving`` (survival under overload/faults) and
``bench_frontier`` (caches/tenancy/continuous batching) drive the
*same* traffic model:

* ``SimClock`` — monotonic simulated time; service costs are explicit
  ``advance`` calls, so every record is bit-stable across machines.
* ``make_sim_encoder`` — the deterministic bag-of-token-counts sparse
  encoder with its cost model (per-dispatch base + per-item marginal,
  the shape that makes batching amortization real on the sim clock).
* ``pump`` — run a synchronous ``ServingLoop`` forward to a target
  sim time, advancing the clock to the next dispatch trigger when a
  tick declines.
* ``poisson_arrivals`` — the open-loop arrival process as a lazy
  generator. Laziness is load-bearing for record stability: each
  inter-arrival gap is drawn when the iterator *resumes*, so a body
  that draws its query from the same ``rng`` between arrivals
  consumes the stream in exactly the order the original inline loops
  did (gap, query, gap, query, …).
* Query samplers — ``uniform_query`` (every query distinct: the
  cache-hostile baseline) and ``ZipfQueries`` (a fixed catalog of
  query texts sampled by Zipf(alpha) popularity rank: the skewed
  traffic real LSR serving sees, and the regime where a result
  cache's hit rate means anything at all).

Constants here are the shared workload shape; benches import them
rather than re-declaring, so the two records stay comparable.
"""

from __future__ import annotations

from typing import Callable, Iterator, Tuple

import numpy as np

from repro.retrieval.sparse_rep import SparseRep
from repro.runtime.serving import ServingLoop

VOCAB = 512
REP_WIDTH = 16
Q_LEN = 12
ENCODE_BASE_S = 0.002       # per-dispatch fixed cost
ENCODE_ITEM_S = 0.0005      # per-request marginal cost


class SimClock:
    """Monotonic simulated time (the loop's ``clock`` callable)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_sim_encoder(clock: SimClock,
                     item_cost: Callable[[], float] = lambda: 0.0,
                     *, vocab: int = VOCAB,
                     rep_width: int = REP_WIDTH,
                     base_s: float = ENCODE_BASE_S,
                     item_s: float = ENCODE_ITEM_S):
    """Deterministic sparse encoder: bag-of-token-counts reps, cost
    modeled as a simulated time advance (base + per-item).

    ``item_cost`` adds the per-request downstream (search) cost to the
    advance — the serving pipeline is encode→search per batch, so
    folding it in here lets the loop's own EWMA see the true service
    time (that estimate drives admission and the pressure signal)."""

    def encode(tokens, mask):
        toks = np.asarray(tokens)
        msk = np.asarray(mask)
        B = toks.shape[0]
        clock.advance(base_s + (item_s + item_cost()) * B)
        vals = np.zeros((B, rep_width), np.float32)
        idxs = np.zeros((B, rep_width), np.int32)
        for i in range(B):
            ids, counts = np.unique(toks[i][msk[i] > 0] % vocab,
                                    return_counts=True)
            order = np.argsort(-counts, kind="stable")[:rep_width]
            k = order.size
            vals[i, :k] = counts[order]
            idxs[i, :k] = ids[order]
        return SparseRep(vals, idxs,
                         (vals > 0).sum(axis=1).astype(np.int32))

    return encode


def pump(loop: ServingLoop, clock: SimClock, until_t: float) -> None:
    """Run the (synchronous) server forward to wall-time ``until_t``:
    tick until the queue is drained or time runs out (service time
    advances the clock inside the encode fn)."""
    pol = loop.encoder.policy
    while clock.t < until_t:
        if not loop.pending:
            clock.t = until_t
            return
        if not loop.tick() and loop.pending:
            trig = loop.pending[0].arrival_t + pol.max_wait_s
            clock.t = min(max(trig, clock.t + 1e-4), until_t)


def poisson_arrivals(rng: np.random.Generator, qps: float,
                     t0: float, t_end: float) -> Iterator[float]:
    """Open-loop Poisson arrival times in ``(t0, t_end)``.

    Lazy by design (module docstring): the next inter-arrival gap is
    drawn from ``rng`` only when the iterator resumes, so per-arrival
    draws made by the loop body interleave into the stream exactly
    where an inline implementation would put them.
    """
    t = t0 + rng.exponential(1.0 / qps)
    while t < t_end:
        yield t
        t += rng.exponential(1.0 / qps)


def uniform_query(rng: np.random.Generator, *, vocab: int = VOCAB,
                  q_len: int = Q_LEN) -> np.ndarray:
    """One fresh uniform-random query — all queries distinct, the
    cache-hostile baseline (and bench_serving's historical draw:
    one ``rng.integers`` call of ``q_len`` tokens)."""
    return rng.integers(1, vocab, size=q_len).astype(np.int32)


class ZipfQueries:
    """A fixed query catalog sampled by Zipf popularity.

    ``n_queries`` distinct query texts are drawn once from ``seed``;
    ``sample`` picks rank ``r`` with probability ∝ 1/(r+1)^alpha, so
    a handful of head queries dominate traffic — the access pattern
    GPUSparse organizes its GPU index around, and the one that makes
    result-cache hit rates meaningful. The expected hit ceiling is
    ``1 - n_distinct/n_samples``; alpha tunes how fast the head
    saturates.
    """

    def __init__(self, n_queries: int, *, alpha: float = 1.1,
                 vocab: int = VOCAB, q_len: int = Q_LEN,
                 seed: int = 0):
        if n_queries <= 0:
            raise ValueError(f"n_queries must be > 0, got {n_queries}")
        catalog_rng = np.random.default_rng(seed)
        self.tokens = catalog_rng.integers(
            1, vocab, size=(n_queries, q_len)).astype(np.int32)
        ranks = np.arange(1, n_queries + 1, dtype=np.float64)
        w = ranks ** -float(alpha)
        self.p = w / w.sum()

    def __len__(self) -> int:
        return len(self.tokens)

    def sample(self, rng: np.random.Generator
               ) -> Tuple[int, np.ndarray]:
        """Draw ``(query_id, tokens)`` — one ``rng`` consumption per
        call."""
        qid = int(rng.choice(len(self.p), p=self.p))
        return qid, self.tokens[qid]
