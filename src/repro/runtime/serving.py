"""Serving runtime: batched sparse-encoding + retrieval.

The LSR serving path has two stages, both built on the paper's
machinery:

1. **Encode** — requests (token sequences) are micro-batched by a
   deadline/size policy and pushed through backbone + Sparton head
   (inference forward only stores the reduced (B, V) output — the
   paper's memory win applies to serving too; the argmax indices
   double as term-level attributions). With the config's rep knobs set
   (``rep_topk``/``rep_threshold``), the output is sparsified on
   device and each request completes as a ``SparseRep`` — only
   ``(B, K)`` crosses to host, never the dense ``(B, V)`` rep.
2. **Retrieve** — encoded queries score a candidate corpus through
   ``repro.retrieval.retrieve``: the inverted impact index is the
   sparse-native production path, the fused streaming kernel
   (``kernels.topk_score``) covers dense 1M-candidate
   ``retrieval_cand`` workloads, and the dense einsum remains the
   tested fallback.

``ServingLoop`` is synchronous-deterministic (tests drive it tick by
tick); a thread wrapper is provided for the example server. Completed
results are handed out by ``take(uid)``, which *pops* — the loop holds
no reference after the caller reads a result, so memory is bounded by
in-flight work, not by total traffic.

``CorpusEngine`` is the online-corpus half: it feeds document batches
through the same batched encoder into an incremental
``engine.IndexBuilder`` (add/remove/flush with tombstones and
compaction — DESIGN.md §8.4), so the served corpus grows online
instead of being rebuilt from scratch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_config_encoder(params: Any, cfg: Any, *, spec: Any = None,
                        mesh: Any = None, jit: bool = True
                        ) -> Callable[[Array, Array], Any]:
    """Canonical ``(tokens, mask) -> reps`` encode fn from a config.

    The single serving-side seam over the unified head API: the
    encoder is built by ``make_encoder`` from ``cfg.head_spec()`` (or
    an explicit ``spec``), so ``head_impl``, pinned/autotuned blocks,
    ``final_logit_softcap`` AND the rep-sparsification knobs are all
    honored — serving paths must not hardcode a head implementation.
    Output is a ``SparseRep`` when the spec sets ``rep_topk`` /
    ``rep_threshold``, else the dense ``(B, V)`` array.
    """
    from repro.core.head_api import make_encoder
    from repro.models import transformer as tfm

    enc = make_encoder(spec if spec is not None else cfg.head_spec(),
                       mesh=mesh)

    def encode(tokens: Array, mask: Array):
        Hs, _ = tfm.forward_hidden(params, cfg, tokens, mask)
        E, b = tfm.head_weights(params, cfg)
        return enc(Hs, E.astype(Hs.dtype), b, mask)

    return jax.jit(encode) if jit else encode


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray          # (len,) int32
    arrival_t: float = 0.0


@dataclasses.dataclass
class BatchPolicy:
    max_batch: int = 32
    max_wait_s: float = 0.005
    pad_to_multiple: int = 16


class BatchedEncoder:
    """Pads + batches requests and runs the jitted encode fn.

    ``encode_fn(tokens (B, S), mask (B, S)) -> reps`` — either a dense
    ``(B, V)`` array or a batched ``SparseRep``; results are split per
    request (numpy row / single-row rep). Bucket padding: sequences are
    padded to the next multiple of ``pad_to_multiple`` so the jit
    cache stays small.
    """

    def __init__(self, encode_fn: Callable[[Array, Array], Any],
                 *, policy: Optional[BatchPolicy] = None):
        self.encode_fn = encode_fn
        self.policy = policy or BatchPolicy()

    def _pad_len(self, n: int) -> int:
        m = self.policy.pad_to_multiple
        return max(m, ((n + m - 1) // m) * m)

    def encode_batch(self, reqs: Sequence[Request]) -> Dict[int, Any]:
        if not reqs:
            return {}
        S = self._pad_len(max(len(r.tokens) for r in reqs))
        B = len(reqs)
        toks = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            n = len(r.tokens)
            toks[i, :n] = r.tokens
            mask[i, :n] = 1
        reps = self.encode_fn(jnp.asarray(toks), jnp.asarray(mask))
        from repro.retrieval.sparse_rep import SparseRep, split_rows

        if isinstance(reps, SparseRep):
            rows: Sequence[Any] = split_rows(reps)
        else:
            rows = np.asarray(reps)
        return {r.uid: rows[i] for i, r in enumerate(reqs)}


class ServingLoop:
    """Deadline/size micro-batching over a request queue.

    ``completed`` holds results only until the caller collects them
    with ``take(uid)`` — results are popped on read, so a loop serving
    heavy traffic stays bounded by in-flight work (a long-lived loop
    whose results were read but never evicted used to grow without
    bound).
    """

    def __init__(self, encoder: BatchedEncoder,
                 *, clock: Callable[[], float] = time.monotonic):
        self.encoder = encoder
        self.clock = clock
        self.pending: List[Request] = []
        self.completed: Dict[int, Any] = {}
        self.batch_sizes: List[int] = []

    def submit(self, req: Request) -> None:
        req.arrival_t = self.clock()
        self.pending.append(req)

    def take(self, uid: int) -> Any:
        """Pop and return the completed result for ``uid``.

        Raises ``KeyError`` when the request hasn't completed (or was
        already taken) — the loop never hands out a result twice.
        """
        return self.completed.pop(uid)

    def tick(self, *, force: bool = False) -> int:
        """Dispatch one batch if policy triggers. Returns batch size."""
        pol = self.encoder.policy
        if not self.pending:
            return 0
        oldest_wait = self.clock() - self.pending[0].arrival_t
        if (len(self.pending) < pol.max_batch
                and oldest_wait < pol.max_wait_s and not force):
            return 0
        batch = self.pending[:pol.max_batch]
        self.pending = self.pending[pol.max_batch:]
        self.completed.update(self.encoder.encode_batch(batch))
        self.batch_sizes.append(len(batch))
        return len(batch)

    def drain(self) -> None:
        while self.pending:
            self.tick(force=True)


class CorpusEngine:
    """Online corpus for the serving loop: encode + index + search.

    Couples a ``BatchedEncoder`` (documents go through the same
    batched encode path as queries) with an ``engine.IndexBuilder``,
    so the corpus grows and shrinks *while serving* instead of being
    frozen at build time:

        eng = CorpusEngine(encoder, vocab_size, quantize=True)
        ids = eng.add_docs(token_arrays)       # encode + buffer
        eng.remove_docs(ids[:3])               # tombstone
        vals, ext_ids = eng.search(q_rep, k)   # flushes, then scores

    ``search`` returns stable *external* doc ids (the ids ``add_docs``
    handed out), surviving compactions. ``keep_forward=True`` enables
    the pruned path (``search(..., method="pruned")``); with
    ``quantize=True`` the base segment is served compressed.

    ``shard_axis``/``n_shards`` pick the base segment's partitioning:
    ``"doc"`` leaves the base a single index (doc sharding is a
    serving-topology choice, not a builder one), ``"term"`` serves it
    as a ``TermShardedIndex`` over ``n_shards`` vocab ranges — the
    large-|V| regime where per-term posting arrays outgrow one HBM
    (DESIGN.md §9).
    """

    def __init__(self, encoder: "BatchedEncoder", vocab_size: int, *,
                 quantize: bool = False, keep_forward: bool = False,
                 merge_frac: float = 0.25,
                 compact_dead_frac: float = 0.25,
                 shard_axis: str = "doc", n_shards: int = 1):
        from repro.retrieval.engine import IndexBuilder

        if shard_axis not in ("doc", "term"):
            raise ValueError(f"shard_axis must be 'doc' or 'term', "
                             f"got {shard_axis!r}")
        self.encoder = encoder
        self.builder = IndexBuilder(
            vocab_size, quantize=quantize, keep_forward=keep_forward,
            merge_frac=merge_frac, compact_dead_frac=compact_dead_frac,
            term_shards=n_shards if shard_axis == "term" else 0)
        self._next_uid = 0

    def add_docs(self, docs: Sequence[np.ndarray],
                 ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Encode token arrays through the batched encoder and buffer
        them into the index; returns their external doc ids.

        Documents are chunked by the encoder's ``policy.max_batch``
        (the policy governs document encoding exactly as it governs
        query micro-batching — one giant batch would blow the jit
        cache and device memory)."""
        from repro.retrieval.sparse_rep import SparseRep, stack_rows

        rows = []
        chunk = max(1, self.encoder.policy.max_batch)
        docs = list(docs)
        for lo in range(0, len(docs), chunk):
            reqs = []
            for tokens in docs[lo:lo + chunk]:
                reqs.append(Request(uid=self._next_uid,
                                    tokens=np.asarray(tokens, np.int32)))
                self._next_uid += 1
            by_uid = self.encoder.encode_batch(reqs)
            rows.extend(by_uid[r.uid] for r in reqs)
        if not all(isinstance(r, SparseRep) for r in rows):
            raise ValueError(
                "CorpusEngine needs a sparse encoder — set the "
                "config's rep_topk/rep_threshold knobs so encode "
                "emits SparseReps")
        return self.builder.add(stack_rows(rows), ids=ids)

    def remove_docs(self, ids: Sequence[int]) -> int:
        return self.builder.remove(ids)

    def flush(self, **kw) -> None:
        self.builder.flush(**kw)

    def search(self, queries, k: int = 10, *, method: str = "auto",
               **kw) -> Tuple[np.ndarray, np.ndarray]:
        return self.builder.search(queries, k, method=method, **kw)

    def stats(self) -> Dict[str, float]:
        return self.builder.stats()


def retrieve_topk(
    q_reps: Array,          # (B, V) query reps (dense or SparseRep)
    doc_matrix: Array,      # (N, V) document reps (or (N, D) dense)
    k: int = 10,
) -> Tuple[Array, Array]:
    """Dense-fallback retrieval: scores + top-k doc ids.

    Back-compat shim over the unified dispatcher — new code should
    call ``repro.retrieval.retrieve(queries, corpus, k, method=...)``
    directly (which also serves the inverted-index and streaming-kernel
    paths).
    """
    from repro.retrieval.score import retrieve

    return retrieve(q_reps, doc_matrix, k, method="dense")
