from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
