from repro.runtime.fault_tolerance import (
    ElasticMeshManager,
    FaultTolerantRunner,
    RunnerConfig,
    StragglerPolicy,
)
from repro.runtime.serving import ServingLoop, Request, BatchedEncoder
