"""gemma2-27b — local/global alternating attention + logit softcaps
[arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000. The 256k
vocabulary matches the paper's xlm-roberta (250k) scenario where the
Sparton gains were largest (26x batch, 2.5x speed). Hybrid
local(4096-window)/global attention => long_500k RUNS (KV for local
layers bounded by the window; global layers decode O(S) with a
sequence-sharded cache).
"""

from repro.configs.base import TransformerConfig, shapes_lm

CONFIG = TransformerConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_head=144,
    d_ff=36864,
    vocab_size=256000,
    sliding_window=4096,
    local_global_alternating=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=10000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    attn_chunk=2048,   # §Perf: -4% memory term vs 512
    # 256k vocab + D=4608: the (8,128,128) v1 default overflows VMEM
    # once the backward scratch is counted — autotune per shape.
    head_block_b=None,
    head_block_s=None,
    head_block_v=None,
)

SMOKE = TransformerConfig(
    name="gemma2-27b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=256,
    vocab_size=512,
    sliding_window=16,
    local_global_alternating=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    remat=False,
)

SHAPES = shapes_lm(long_ok=True)
