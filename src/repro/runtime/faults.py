"""Deterministic fault injection for the serving runtime.

Every hardening behavior in ``runtime/serving.py`` — bisect isolation
of poison batches, OOM-driven batch-cap adaptation, shedding under
latency spikes — must be unit-testable without a GPU and without
flaky randomness. ``FaultInjector`` wraps any callable (the encode fn,
a search fn) with a *plan*: a list of plain dicts, each naming a
trigger, an action, and a budget. Plans are data, so tests and the
traffic-simulation bench (``benchmarks/bench_serving.py``) describe
fault scenarios declaratively and DESIGN.md §10 documents the format
once.

Plan format — one dict per rule::

    {"on":  {"call": 5}              # the 5th call (0-based), or
            {"every": 7}             # every 7th call (calls 6, 13, ...), or
            {"token": 17}            # any row of arg 0 contains token 17, or
            {"prob": 0.05},          # seeded Bernoulli per call
     "do":  "raise" | "delay",       # default "raise"
     "exc": "fault" | "transient" | "oom",   # default "fault"
     "times": 3,                     # fire at most 3 times; None/absent
                                     # = persistent (fires forever)
     "delay_s": 0.02}                # only for "do": "delay"

* ``"token"`` is the poison-request trigger: a *persistent* token rule
  makes every batch containing that request fail, which is exactly the
  shape the serving loop's bisect isolation must survive — clean
  neighbours served, the poisoned uid failed.
* ``"times": 1`` models a transient fault (fails once, then heals):
  the bisect retry serves the whole batch.
* ``"exc": "oom"`` raises :class:`ResourceExhausted`, the OOM-shaped
  error class the loop's adaptive batch cap keys on.
* ``"prob"`` draws from a generator seeded by ``seed + rule index`` —
  the same plan and seed always fire on the same calls.

``"delay"`` rules call the injected ``sleep`` (a fake-clock ``advance``
in tests/bench, ``time.sleep`` by default) and then fall through to the
wrapped fn — a latency spike, not a failure.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class FaultError(RuntimeError):
    """Base class for injected failures."""


class TransientFault(FaultError):
    """A failure expected to heal on retry (network blip, preemption)."""


class ResourceExhausted(FaultError):
    """OOM-shaped: the device pushed back on the batch size."""


_EXC: Dict[str, type] = {
    "fault": FaultError,
    "transient": TransientFault,
    "oom": ResourceExhausted,
}

# Markers real accelerator stacks put in OOM errors (XLA raises
# RESOURCE_EXHAUSTED; CUDA says "out of memory") — matched on the
# exception type name + message so the serving loop's cap adaptation
# works on real errors, not just injected ones.
_OOM_MARKERS = ("resource_exhausted", "resourceexhausted",
                "out of memory", "oom")


def is_oom_error(e: BaseException) -> bool:
    """Does this exception look like the device ran out of memory?"""
    if isinstance(e, ResourceExhausted):
        return True
    text = f"{type(e).__name__}: {e}".lower()
    return any(m in text for m in _OOM_MARKERS)


_TRIGGERS = ("call", "every", "token", "prob")


@dataclasses.dataclass
class _Rule:
    on: Dict[str, Any]
    do: str
    exc: str
    times: Optional[int]
    delay_s: float
    rng: Optional[np.random.Generator]
    fired: int = 0

    @property
    def exhausted(self) -> bool:
        return self.times is not None and self.fired >= self.times


def _compile(plan: Sequence[Dict[str, Any]], seed: int) -> List[_Rule]:
    rules = []
    for ri, spec in enumerate(plan):
        on = dict(spec.get("on", {}))
        trigger = [t for t in _TRIGGERS if t in on]
        if len(trigger) != 1:
            raise ValueError(
                f"rule {ri}: 'on' needs exactly one of {_TRIGGERS}, "
                f"got {sorted(on)}")
        do = spec.get("do", "raise")
        if do not in ("raise", "delay"):
            raise ValueError(f"rule {ri}: unknown do={do!r}")
        exc = spec.get("exc", "fault")
        if exc not in _EXC:
            raise ValueError(f"rule {ri}: unknown exc={exc!r} "
                             f"(one of {sorted(_EXC)})")
        rng = (np.random.default_rng(seed + ri)
               if trigger[0] == "prob" else None)
        rules.append(_Rule(on=on, do=do, exc=exc,
                           times=spec.get("times"),
                           delay_s=float(spec.get("delay_s", 0.0)),
                           rng=rng))
    return rules


class FaultInjector:
    """Wrap ``fn`` with a deterministic fault plan (module docstring).

    Call-compatible with the wrapped fn. ``calls`` counts invocations,
    ``log`` records ``(call_idx, rule_idx, action)`` for every firing —
    tests assert against it, the bench reports it.
    """

    def __init__(self, fn: Callable[..., Any],
                 plan: Sequence[Dict[str, Any]], *, seed: int = 0,
                 sleep: Optional[Callable[[float], None]] = None):
        self.fn = fn
        self.rules = _compile(plan, seed)
        self.sleep = sleep if sleep is not None else time.sleep
        self.calls = 0
        self.log: List[Tuple[int, int, str]] = []

    def _matches(self, rule: _Rule, call_idx: int, first_arg) -> bool:
        on = rule.on
        if "call" in on:
            return call_idx == int(on["call"])
        if "every" in on:
            n = int(on["every"])
            return n > 0 and (call_idx + 1) % n == 0
        if "token" in on:
            if first_arg is None:
                return False
            return bool(np.any(np.asarray(first_arg) == on["token"]))
        if "prob" in on:
            # always consume a draw so the stream stays aligned with
            # the call index regardless of other rules' firings
            return bool(rule.rng.random() < float(on["prob"]))
        return False

    def __call__(self, *args, **kwargs):
        call_idx = self.calls
        self.calls += 1
        first_arg = args[0] if args else None
        for ri, rule in enumerate(self.rules):
            if rule.exhausted or not self._matches(rule, call_idx,
                                                   first_arg):
                continue
            rule.fired += 1
            self.log.append((call_idx, ri, rule.do))
            if rule.do == "delay":
                self.sleep(rule.delay_s)
                continue        # a spike, not a failure — keep going
            raise _EXC[rule.exc](
                f"injected {rule.exc} (call {call_idx}, rule {ri})")
        return self.fn(*args, **kwargs)


def inject_faults(fn: Callable[..., Any],
                  plan: Sequence[Dict[str, Any]], *, seed: int = 0,
                  sleep: Optional[Callable[[float], None]] = None
                  ) -> FaultInjector:
    """Sugar: ``inject_faults(encode, plan)`` -> wrapped callable."""
    return FaultInjector(fn, plan, seed=seed, sleep=sleep)
