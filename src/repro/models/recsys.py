"""RecSys architectures: DLRM, xDeepFM, DIEN, Wide&Deep.

All four share the same skeleton — huge sparse embedding tables
(resolved via ``repro.sparse.embedding_bag``; JAX has no EmbeddingBag,
so the gather + segment-reduce IS system code here) feeding a
feature-interaction op and a small MLP:

* **DLRM** (MLPerf config) — dense features through a bottom MLP, dot
  interaction between all pairs of (dense, sparse) embeddings, top MLP.
* **xDeepFM** — Compressed Interaction Network (CIN): outer-product
  feature maps compressed per layer, plus a plain DNN and linear part.
* **DIEN** — GRU over the user behaviour sequence, then an
  attention-gated AUGRU second pass against the target item.
* **Wide&Deep** — wide linear part over one-hot ids + deep MLP over
  concatenated embeddings.

The ``retrieval_cand`` shape (score 1M candidates for one query) does
not run these interaction stacks per candidate — it uses the fused
streaming top-k scorer (``repro.kernels.topk_score``), the Sparton-idea
transfer documented in DESIGN.md §4.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _mlp_init(key, dims: Tuple[int, ...], dtype) -> List[Dict[str, Array]]:
    keys = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": jax.random.normal(k, (dims[i], dims[i + 1]), dtype)
            * dims[i] ** -0.5,
            "b": jnp.zeros((dims[i + 1],), dtype),
        }
        for i, k in enumerate(keys)
    ]


def _mlp_apply(layers, x, *, final_act: bool = False) -> Array:
    for li, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if li < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


ROW_PAD = 4096  # table rows padded for 512-device row sharding


def padded_rows(rows: int) -> int:
    """Rows padded to a 512-divisible multiple (sharding invariant)."""
    return rows + ((-rows) % ROW_PAD)


def _embed_init(key, n_tables: int, rows_per_table: Tuple[int, ...],
                dim: int, dtype) -> List[Array]:
    keys = jax.random.split(key, n_tables)
    return [
        jax.random.normal(k, (padded_rows(rows), dim), dtype) * dim ** -0.5
        for k, rows in zip(keys, rows_per_table)
    ]


def _lookup_all(tables: List[Array], idx: Array) -> Array:
    """idx: (batch, n_fields) -> (batch, n_fields, dim)."""
    outs = [jnp.take(t, idx[:, f], axis=0) for f, t in enumerate(tables)]
    return jnp.stack(outs, axis=1)


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------

def init_dlrm(key: jax.Array, cfg: RecSysConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    n_emb = cfg.n_sparse
    d = cfg.embed_dim
    # interaction: pairwise dots among (1 bottom-mlp output + n_sparse)
    n_f = n_emb + 1
    n_int = n_f * (n_f - 1) // 2
    top_in = d + n_int
    return {
        "tables": _embed_init(k1, n_emb, cfg.table_sizes, d, dtype),
        "bot_mlp": _mlp_init(k2, cfg.bot_mlp, dtype),
        "top_mlp": _mlp_init(k3, (top_in,) + cfg.top_mlp, dtype),
    }


def dlrm_forward(params: Params, cfg: RecSysConfig,
                 dense: Array, sparse_idx: Array) -> Array:
    """dense: (B, n_dense) f32; sparse_idx: (B, n_sparse) i32 -> (B,) logit."""
    x_bot = _mlp_apply(params["bot_mlp"], dense, final_act=True)  # (B, d)
    emb = _lookup_all(params["tables"], sparse_idx)               # (B, F, d)
    feats = jnp.concatenate([x_bot[:, None, :], emb], axis=1)     # (B, F+1, d)
    # pairwise dot interaction (upper triangle, no diagonal)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    n_f = feats.shape[1]
    iu, ju = jnp.triu_indices(n_f, k=1)
    inter_flat = inter[:, iu, ju]                                  # (B, n_int)
    top_in = jnp.concatenate([x_bot, inter_flat], axis=-1)
    return _mlp_apply(params["top_mlp"], top_in)[:, 0]


# ---------------------------------------------------------------------------
# xDeepFM
# ---------------------------------------------------------------------------

def init_xdeepfm(key: jax.Array, cfg: RecSysConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    m = cfg.n_sparse
    d = cfg.embed_dim
    cin_w = []
    h_prev = m
    kc = jax.random.split(k3, len(cfg.cin_layers))
    for h_k, kk in zip(cfg.cin_layers, kc):
        cin_w.append(jax.random.normal(kk, (h_prev * m, h_k), dtype)
                     * (h_prev * m) ** -0.5)
        h_prev = h_k
    dnn_in = m * d
    cin_out = sum(cfg.cin_layers)
    return {
        "tables": _embed_init(k1, m, cfg.table_sizes, d, dtype),
        "linear": _embed_init(k2, m, cfg.table_sizes, 1, dtype),
        "cin": cin_w,
        "dnn": _mlp_init(k4, (dnn_in,) + cfg.mlp, dtype),
        "out": _mlp_init(k5, (cfg.mlp[-1] + cin_out + 1, 1), dtype),
    }


def xdeepfm_forward(params: Params, cfg: RecSysConfig,
                    sparse_idx: Array) -> Array:
    """sparse_idx: (B, m) -> (B,) logit."""
    B = sparse_idx.shape[0]
    m, d = cfg.n_sparse, cfg.embed_dim
    x0 = _lookup_all(params["tables"], sparse_idx)      # (B, m, d)
    lin = _lookup_all(params["linear"], sparse_idx)     # (B, m, 1)
    lin_term = jnp.sum(lin, axis=(1, 2), keepdims=False)[:, None]  # (B, 1)

    # CIN: x^k[b, h, d] = sum_{i,j} W^k[i*m+j, h] x^{k-1}[b,i,d] x^0[b,j,d]
    xs = x0
    pooled = []
    for w in params["cin"]:
        h_prev = xs.shape[1]
        z = jnp.einsum("bid,bjd->bijd", xs, x0).reshape(B, h_prev * m, d)
        xs = jnp.einsum("bpd,ph->bhd", z, w)
        pooled.append(jnp.sum(xs, axis=-1))             # (B, h_k)
    cin_out = jnp.concatenate(pooled, axis=-1)

    dnn_out = _mlp_apply(params["dnn"], x0.reshape(B, m * d),
                         final_act=True)
    final_in = jnp.concatenate([dnn_out, cin_out, lin_term], axis=-1)
    return _mlp_apply(params["out"], final_in)[:, 0]


# ---------------------------------------------------------------------------
# DIEN
# ---------------------------------------------------------------------------

def _gru_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (d_in, 3 * d_h), dtype) * d_in ** -0.5,
        "u": jax.random.normal(k2, (d_h, 3 * d_h), dtype) * d_h ** -0.5,
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def _gru_cell(p, x, h, update_gate_scale=None):
    """Standard GRU cell; AUGRU scales the update gate by attention."""
    gx = x @ p["w"] + p["b"]
    gh = h @ p["u"]
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    if update_gate_scale is not None:
        z = z * update_gate_scale[:, None]
    n = jnp.tanh(nx + r * nh)
    return (1 - z) * n + z * h


def init_dien(key: jax.Array, cfg: RecSysConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    d = cfg.embed_dim
    g = cfg.gru_dim
    # item embedding table (behaviour sequence + target share the table)
    return {
        "item_table": jax.random.normal(
            ks[0], (padded_rows(cfg.table_sizes[0]), d), dtype) * d ** -0.5,
        "gru1": _gru_init(ks[1], d, g, dtype),
        "augru": _gru_init(ks[2], g, g, dtype),
        "att": _mlp_init(ks[3], (2 * g, 36, 1), dtype),
        "item_proj": _mlp_init(ks[4], (d, g), dtype),
        "mlp": _mlp_init(ks[5], (2 * g + d,) + cfg.mlp + (1,), dtype),
    }


def dien_forward(params: Params, cfg: RecSysConfig,
                 hist_idx: Array, target_idx: Array,
                 unroll: int = 1) -> Array:
    """hist_idx: (B, T) behaviour ids; target_idx: (B,) -> (B,) logit.

    ``unroll`` replicates the GRU/AUGRU scan bodies for cost-probe
    lowering (roofline.py)."""
    B, T = hist_idx.shape
    g = cfg.gru_dim
    hist = jnp.take(params["item_table"], hist_idx, axis=0)   # (B, T, d)
    tgt = jnp.take(params["item_table"], target_idx, axis=0)  # (B, d)
    tgt_h = _mlp_apply(params["item_proj"], tgt)              # (B, g)

    # interest extraction: GRU over the sequence
    def step1(h, x):
        h2 = _gru_cell(params["gru1"], x, h)
        return h2, h2
    h0 = jnp.zeros((B, g), hist.dtype)
    _, seq_h = jax.lax.scan(step1, h0, jnp.moveaxis(hist, 1, 0),
                            unroll=unroll)
    seq_h = jnp.moveaxis(seq_h, 0, 1)                         # (B, T, g)

    # interest evolution: attention scores vs target gate AUGRU updates
    att_in = jnp.concatenate(
        [seq_h, jnp.broadcast_to(tgt_h[:, None, :], seq_h.shape)], axis=-1)
    att = _mlp_apply(params["att"], att_in)[..., 0]           # (B, T)
    att = jax.nn.softmax(att, axis=-1)

    def step2(h, xs):
        x, a = xs
        h2 = _gru_cell(params["augru"], x, h, update_gate_scale=1.0 - a)
        return h2, None
    final_h, _ = jax.lax.scan(
        step2, jnp.zeros((B, g), hist.dtype),
        (jnp.moveaxis(seq_h, 1, 0), jnp.moveaxis(att, 1, 0)),
        unroll=unroll)

    mlp_in = jnp.concatenate([final_h, tgt_h, tgt], axis=-1)
    return _mlp_apply(params["mlp"], mlp_in)[:, 0]


# ---------------------------------------------------------------------------
# Wide & Deep
# ---------------------------------------------------------------------------

def init_wide_deep(key: jax.Array, cfg: RecSysConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    m, d = cfg.n_sparse, cfg.embed_dim
    return {
        "tables": _embed_init(k1, m, cfg.table_sizes, d, dtype),
        "wide": _embed_init(k2, m, cfg.table_sizes, 1, dtype),
        "deep": _mlp_init(k3, (m * d,) + cfg.mlp + (1,), dtype),
    }


def wide_deep_forward(params: Params, cfg: RecSysConfig,
                      sparse_idx: Array) -> Array:
    B = sparse_idx.shape[0]
    m, d = cfg.n_sparse, cfg.embed_dim
    emb = _lookup_all(params["tables"], sparse_idx)    # (B, m, d)
    wide = _lookup_all(params["wide"], sparse_idx)     # (B, m, 1)
    deep = _mlp_apply(params["deep"], emb.reshape(B, m * d))
    return deep[:, 0] + jnp.sum(wide, axis=(1, 2))


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

INIT_FNS = {
    "dot": init_dlrm,
    "cin": init_xdeepfm,
    "augru": init_dien,
    "concat": init_wide_deep,
}


def init_params(key: jax.Array, cfg: RecSysConfig) -> Params:
    return INIT_FNS[cfg.interaction](key, cfg)


def forward(params: Params, cfg: RecSysConfig, batch: Dict[str, Array],
            unroll: int = 1) -> Array:
    """Unified forward: batch dict carries the per-family inputs."""
    if cfg.interaction == "dot":
        return dlrm_forward(params, cfg, batch["dense"], batch["sparse_idx"])
    if cfg.interaction == "cin":
        return xdeepfm_forward(params, cfg, batch["sparse_idx"])
    if cfg.interaction == "augru":
        return dien_forward(params, cfg, batch["hist_idx"],
                            batch["target_idx"], unroll=unroll)
    if cfg.interaction == "concat":
        return wide_deep_forward(params, cfg, batch["sparse_idx"])
    raise ValueError(f"unknown interaction {cfg.interaction!r}")


def user_embedding(params: Params, cfg: RecSysConfig,
                   batch: Dict[str, Array]) -> Array:
    """Query-side embedding for the retrieval_cand shape.

    Produces a (B, embed_dim) query vector from the interaction trunk —
    the candidate scoring itself runs through the fused top-k kernel.
    """
    if cfg.interaction == "dot":
        return _mlp_apply(params["bot_mlp"], batch["dense"], final_act=True)
    if cfg.interaction == "augru":
        hist = jnp.take(params["item_table"], batch["hist_idx"], axis=0)
        return jnp.mean(hist, axis=1)
    # cin / concat: mean of field embeddings
    emb = _lookup_all(params["tables"], batch["sparse_idx"])
    return jnp.mean(emb, axis=1)
