"""DLRM — MLPerf benchmark config (Criteo 1TB) [arXiv:1906.00091].

n_dense=13 n_sparse=26 embed_dim=128 bot=13-512-256-128
top=1024-1024-512-256-1, dot interaction. Table sizes are the MLPerf v1
Criteo-1TB day-feature cardinalities with max-ind-range=40M hashing —
the three ~40M-row tables are what force row-sharding
(repro/sparse/sharded_embedding.py).
"""

from repro.configs.base import RecSysConfig, SHAPES_RECSYS

# MLPerf DLRM (terabyte, max-ind-range=40000000) per-table rows
MLPERF_TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

CONFIG = RecSysConfig(
    name="dlrm-mlperf",
    interaction="dot",
    n_dense=13,
    n_sparse=26,
    embed_dim=128,
    table_sizes=MLPERF_TABLE_SIZES,
    bot_mlp=(13, 512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
)

SMOKE = RecSysConfig(
    name="dlrm-smoke",
    interaction="dot",
    n_dense=13,
    n_sparse=4,
    embed_dim=16,
    table_sizes=(100, 50, 200, 30),
    bot_mlp=(13, 32, 16),
    top_mlp=(64, 32, 1),
)

SHAPES = SHAPES_RECSYS
