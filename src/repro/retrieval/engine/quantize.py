"""Posting-list compression: quantized impacts + delta-encoded doc ids
(DESIGN.md §8.2).

A raw posting costs 8 bytes (i32 doc id + f32 impact). The quantized
layout stores the same posting in 1.5 bytes:

* **Impacts — nibble-packed u4, per-term affine.** LSR impacts within
  one posting list cluster tightly (a term's weight is IDF-like across
  documents), so a per-term affine code ``val ~= lo[t] + (q-1) *
  (hi[t]-lo[t])/14`` with q in 1..15 keeps the dequantization error
  <= spread/28. Code 0 is reserved for phantom postings (see below),
  two codes pack per byte. ``lo``/``hi`` are stored f16 per term; the
  build quantizes against the f16-rounded bounds so build and scorer
  agree bit-exactly.

* **Doc ids — delta encoding with escape phantoms.** Posting lists
  are doc-id ascending, so ids are stored as gaps. A gap g > the
  delta dtype's escape value E is encoded as ``g // E`` phantom
  postings (delta=E, code=0) before the real posting's ``g % E``: the
  scorer's running cumsum passes through phantoms, whose code-0
  impact contributes exactly 0. The first posting's "gap" is its
  absolute doc id. The build picks u8 or u16 deltas by total bytes:
  dense posting lists (small gaps) take u8 (1.5 B/posting); sparse
  lists whose gaps would drown u8 in phantoms take u16 (2.5
  B/posting) instead of silently exploding the index and the
  per-query gather window.

The scorer (``quantized_scores``) walks the same padded per-term
windows as the exact impact scorer and dequantizes on the fly inside
the jitted gather — unpack nibble, affine-decode, cumsum the deltas to
absolute doc ids, segment-sum. No dequantized copy of the index ever
exists in memory.

On LSR-shaped corpora this is a >= 4x index-size reduction at
unchanged top-k ids (pinned by tests and ``benchmarks/bench_engine.py``
— the asymptote is 8 B / 1.5 B ~= 5.3x, minus O(V) metadata).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.index import InvertedIndex
from repro.retrieval.sparse_rep import SparseRep
from repro.sparse.segment import segment_sum

Array = jax.Array

_LEVELS = 14          # q in 1..15 -> 14 steps between lo and hi
_DELTA_DTYPES = ((np.uint8, 255), (np.uint16, 65535))  # (dtype, escape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedIndex:
    term_starts: Array      # (V,) i32 — offsets in *postings* units
    term_lens: Array        # (V,) u16/i32 — expanded list lengths
    packed_vals: Array      # (ceil(P/2),) u8 — two u4 codes per byte
    deltas: Array           # (P,) u8/u16 — doc-id gaps (max = escape)
    term_lo: Array          # (V,) f16 — affine low per term
    term_hi: Array          # (V,) f16 — affine high per term
    n_docs: int             # static
    vocab_size: int         # static
    max_postings: int       # static — longest *expanded* list (>= 1)
    n_source_postings: int  # static — postings before phantom expansion

    def tree_flatten(self):
        children = (self.term_starts, self.term_lens, self.packed_vals,
                    self.deltas, self.term_lo, self.term_hi)
        aux = (self.n_docs, self.vocab_size, self.max_postings,
               self.n_source_postings)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_postings(self) -> int:
        return self.deltas.shape[0]

    def memory_bytes(self) -> int:
        return int(sum(np.asarray(a).nbytes for a in (
            self.term_starts, self.term_lens, self.packed_vals,
            self.deltas, self.term_lo, self.term_hi)))

    def stats(self) -> Dict[str, float]:
        return {
            "n_docs": self.n_docs,
            "vocab_size": self.vocab_size,
            "n_postings": self.n_postings,
            "n_source_postings": self.n_source_postings,
            "phantom_frac": 1.0 - self.n_source_postings
            / max(self.n_postings, 1),
            "max_postings": self.max_postings,
            "memory_bytes": self.memory_bytes(),
        }


def quantize_index(index: InvertedIndex) -> QuantizedIndex:
    """Compress an ``InvertedIndex`` (host-side numpy build)."""
    V = index.vocab_size
    starts = np.asarray(index.term_starts, np.int64)
    lens = np.asarray(index.term_lens, np.int64)
    docs = np.asarray(index.postings_doc, np.int64)
    vals = np.asarray(index.postings_val, np.float32)
    P = docs.shape[0]
    has_real = lens.sum() > 0

    # per-term affine bounds over the *source* impacts, f16-rounded so
    # the scorer's decode matches the build's encode exactly
    term_of = np.repeat(np.arange(V), lens)          # (P_real,)
    real = slice(0, term_of.shape[0])
    lo = np.full(V, np.inf, np.float32)
    hi = np.zeros(V, np.float32)
    if has_real:
        np.minimum.at(lo, term_of, vals[real])
        np.maximum.at(hi, term_of, vals[real])
    lo[~np.isfinite(lo)] = 0.0
    lo16 = lo.astype(np.float16)
    hi16 = hi.astype(np.float16)
    lo_r = lo16.astype(np.float32)
    step = (hi16.astype(np.float32) - lo_r) / _LEVELS

    # u4 codes (1..15) for real postings
    if has_real:
        s = step[term_of]
        q = np.where(s > 0,
                     np.rint((vals[real] - lo_r[term_of])
                             / np.where(s > 0, s, 1.0)),
                     0.0)
        codes = (1 + np.clip(q, 0, _LEVELS)).astype(np.uint8)
    else:
        codes = np.ones(0, np.uint8)

    # doc-id gaps (reset at term boundaries; first gap = absolute id)
    gaps = np.empty(term_of.shape[0], np.int64)
    if has_real:
        d = docs[real]
        gaps[:] = d
        gaps[1:] -= d[:-1]
        first = starts[lens > 0]
        gaps[first] = d[first]

    # escape expansion: gap = escape*m + r -> m phantoms + the real
    # entry. Pick the delta width minimizing total posting bytes —
    # u8 for dense lists, u16 when large gaps would drown u8 in
    # phantoms (and blow up max_postings, the per-query gather width).
    def posting_bytes(dtype, escape):
        n = int((1 + gaps // escape).sum()) if has_real else 1
        return n * (np.dtype(dtype).itemsize + 0.5)

    dtype, escape = min(_DELTA_DTYPES,
                        key=lambda de: posting_bytes(*de))
    m = gaps // escape
    counts = (1 + m).astype(np.int64)
    Pq = int(counts.sum()) if has_real else 1
    out_deltas = np.full(Pq, escape, dtype)
    out_codes = np.zeros(Pq, np.uint8)
    if has_real:
        real_pos = np.cumsum(counts) - 1
        out_deltas[real_pos] = (gaps % escape).astype(dtype)
        out_codes[real_pos] = codes
        new_lens = np.zeros(V, np.int64)
        np.add.at(new_lens, term_of, counts)
    else:
        out_deltas[0] = 0
        new_lens = np.zeros(V, np.int64)
    new_starts = np.zeros(V, np.int64)
    np.cumsum(new_lens[:-1], out=new_starts[1:])

    # nibble-pack: even posting -> low nibble, odd -> high
    padded = np.zeros(Pq + (Pq & 1), np.uint8)
    padded[:Pq] = out_codes
    packed = (padded[0::2] | (padded[1::2] << 4)).astype(np.uint8)

    lens_dtype = np.uint16 if new_lens.max(initial=0) < 2**16 else np.int32
    return QuantizedIndex(
        term_starts=jnp.asarray(new_starts.astype(np.int32)),
        term_lens=jnp.asarray(new_lens.astype(lens_dtype)),
        packed_vals=jnp.asarray(packed),
        deltas=jnp.asarray(out_deltas),
        term_lo=jnp.asarray(lo16),
        term_hi=jnp.asarray(hi16),
        n_docs=index.n_docs,
        vocab_size=index.vocab_size,
        max_postings=max(int(new_lens.max(initial=0)), 1),
        n_source_postings=int(lens.sum()),
    )


def quantized_scores(queries: SparseRep, index: QuantizedIndex) -> Array:
    """Dense ``(B, n_docs)`` scores, dequantizing on the fly.

    Identical window walk to ``score.impact_scores``; per lane the
    u4 code is unpacked from its byte, affine-decoded against the
    term's f16 bounds, and the u8 gaps are cumsum-ed into absolute doc
    ids. Phantom lanes (code 0) decode to weight 0 and only advance
    the cumsum.
    """
    l_max = index.max_postings
    p_total = index.deltas.shape[0]
    lane = jnp.arange(l_max, dtype=jnp.int32)
    step = (index.term_hi.astype(jnp.float32)
            - index.term_lo.astype(jnp.float32)) / _LEVELS

    def one(qv: Array, qi: Array) -> Array:
        starts = index.term_starts[qi]                     # (Q,)
        lens = index.term_lens[qi].astype(jnp.int32)       # (Q,)
        pos = starts[:, None] + lane[None, :]              # (Q, Lmax)
        valid = (lane[None, :] < lens[:, None]) & (qv > 0)[:, None]
        pos = jnp.clip(pos, 0, p_total - 1)

        byte = index.packed_vals[pos >> 1].astype(jnp.int32)
        code = jnp.where((pos & 1) == 1, byte >> 4, byte & 0xF)
        code = jnp.where(valid, code, 0)

        gaps = jnp.where(valid, index.deltas[pos].astype(jnp.int32), 0)
        docs = jnp.cumsum(gaps, axis=1)                    # absolute ids

        val = (index.term_lo[qi].astype(jnp.float32)[:, None]
               + (code - 1) * step[qi][:, None])
        w = jnp.where(code > 0, val, 0.0) * qv[:, None]
        return segment_sum(w.ravel(), docs.ravel(), index.n_docs)

    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    return jax.vmap(one)(qv, qi)


@functools.partial(jax.jit, static_argnames=("k",))
def _quantized_retrieve(queries: SparseRep, index: QuantizedIndex,
                        k: int) -> Tuple[Array, Array]:
    scores = quantized_scores(queries, index)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def quantized_retrieve(queries: SparseRep, index: QuantizedIndex,
                       k: int = 10) -> Tuple[Array, Array]:
    """Top-k over the compressed index — same contract as ``retrieve``."""
    return _quantized_retrieve(queries, index, min(k, index.n_docs))


@jax.jit
def _fused_q_windows(queries: SparseRep, index: QuantizedIndex
                     ) -> Tuple[Array, ...]:
    """Gather the *packed* per-query windows for the fused kernel.

    Unlike ``quantized_scores``, nothing is decoded here: the kernel
    receives the raw packed bytes and gaps plus the per-term affine
    metadata, and the nibble unpack / affine decode / gap cumsum all
    happen inside the Pallas grid (kernels/impact_score.py) — the
    standalone dequant materialization is gone.
    """
    l_max = index.max_postings
    p_total = index.deltas.shape[0]
    lane = jnp.arange(l_max, dtype=jnp.int32)
    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    starts = index.term_starts[qi]                         # (B, Q)
    lens = index.term_lens[qi].astype(jnp.int32)           # (B, Q)
    pos = starts[:, :, None] + lane[None, None, :]         # (B, Q, L)
    pos = jnp.clip(pos, 0, p_total - 1)
    byte_win = index.packed_vals[pos >> 1].astype(jnp.int32)
    gap_win = index.deltas[pos].astype(jnp.int32)
    lo = index.term_lo[qi].astype(jnp.float32)
    step = (index.term_hi[qi].astype(jnp.float32) - lo) / _LEVELS
    return byte_win, gap_win, starts, lens, qv, lo, step


def fused_quantized_retrieve(
    queries: SparseRep,
    index: QuantizedIndex,
    k: int = 10,
    *,
    block_n: Optional[int] = None,
    block_w: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """Fused-kernel top-k over the compressed index — id-identical to
    ``quantized_retrieve`` (the in-kernel decode is bit-exact against
    the same f16-rounded bounds).

    None blocks resolve through the autotune cache/heuristic under the
    ``u4`` ``_impact`` keys; ``interpret`` defaults to the Pallas
    interpreter off-TPU.
    """
    from repro.kernels.autotune import resolve_impact_blocks
    from repro.kernels.impact_score import fused_quantized_topk

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = queries.values.reshape(-1, queries.width).shape[0]
    block_n, block_w = resolve_impact_blocks(
        b, queries.width, index.max_postings, index.n_docs,
        block_n, block_w, variant="u4")
    byte_win, gap_win, starts, lens, qv, lo, step = _fused_q_windows(
        queries, index)
    return fused_quantized_topk(
        byte_win, gap_win, starts, lens, qv, lo, step,
        n_docs=index.n_docs, k=min(k, index.n_docs),
        block_n=block_n, block_w=block_w, interpret=interpret)
