"""Fused impact-scoring kernel tests (kernels/impact_score,
DESIGN.md §12).

The acceptance anchor is id parity: ``method="fused"`` must return doc
ids identical to ``method="impact"`` on the graded benchmark corpus
(scores bit-close), and the fused-quantized entry point identical to
the unfused ``quantized_retrieve`` on the *same* compressed index —
quantization error is shared, so the comparison is exact, not
tolerance-based. The property test drives both with values that are
multiples of 1/8 so every partial sum is exactly representable in f32
and tie-breaks are deterministic; edge cases (empty queries, k >= N,
duplicate scores, W == 0) are pinned individually. The subprocess test
mirrors ``test_engine``'s forced-host-device pattern so CI's
multidevice job exercises the kernel under the interpreter at 1/2/4
devices.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.synthetic import lsr_impact_corpus
from repro.kernels._common import NEG_INF
from repro.kernels.impact_score import (fused_impact_topk,
                                        fused_window_bytes)
from repro.retrieval import (build_inverted_index, quantize_index,
                             retrieve, sparsify_topk)
from repro.retrieval.engine.quantize import (fused_quantized_retrieve,
                                             quantized_retrieve)
from repro.retrieval.score import fused_retrieve

K = 10
BENCH = dict(n_docs=384, vocab=512, doc_nnz=32, n_queries=6, q_nnz=28)


@pytest.fixture(scope="module")
def graded():
    """Pinned graded corpus + the exact impact baseline the fused
    kernel must reproduce id-for-id."""
    data = lsr_impact_corpus(**BENCH)
    q = sparsify_topk(jnp.asarray(data["queries"]), BENCH["q_nnz"])
    d = sparsify_topk(jnp.asarray(data["docs"]), BENCH["doc_nnz"])
    raw = build_inverted_index(d, BENCH["vocab"])
    vals, idx = retrieve(q, raw, K, method="impact")
    return {"q": q, "d": d, "raw": raw,
            "vals": np.asarray(vals), "idx": np.asarray(idx)}


# ---------------------------------------------------------------------------
# raw-index parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_n,block_w", [(64, 128), (128, 256),
                                             (512, 128)])
def test_fused_matches_impact_across_block_sizes(graded, block_n,
                                                 block_w):
    """Acceptance: identical ids and scores for every tile/chunk
    geometry, including tile counts that don't divide N (384)."""
    vals, idx = fused_retrieve(graded["q"], graded["raw"], K,
                               block_n=block_n, block_w=block_w,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)


def test_dispatcher_fused_with_autotuned_blocks(graded):
    """block_*=None resolves through the autotune cache/heuristic
    (fresh cache per test via the conftest fixture) — still id-exact."""
    vals, idx = retrieve(graded["q"], graded["raw"], K, method="fused",
                         interpret=True)
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])


def test_fused_empty_query_rows(graded):
    """All-zero queries score every doc 0: ties break to the lowest
    doc id, exactly like lax.top_k over the zero score matrix."""
    z = sparsify_topk(jnp.zeros((2, BENCH["vocab"])), 4)
    v_ref, i_ref = retrieve(z, graded["raw"], K, method="impact")
    v_f, i_f = fused_retrieve(z, graded["raw"], K, block_n=64,
                              block_w=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(i_f),
                                  np.tile(np.arange(K), (2, 1)))
    assert (np.asarray(v_f) == 0).all()


@pytest.mark.filterwarnings("ignore:build_inverted_index")
def test_fused_duplicate_scores_tie_to_lowest_id():
    """A corpus of identical docs makes every score a duplicate — the
    running merge must hand back ascending doc ids like the
    reference."""
    n, vocab = 37, 64
    m = np.zeros((n, vocab), np.float32)
    m[:, [3, 7, 11]] = 1.0                     # every doc identical
    d = sparsify_topk(jnp.asarray(m), 4)
    q = sparsify_topk(jnp.asarray(m[:1]), 4)
    idxobj = build_inverted_index(d, vocab)
    v_ref, i_ref = retrieve(q, idxobj, 8, method="impact")
    v_f, i_f = fused_retrieve(q, idxobj, 8, block_n=8, block_w=128,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(i_f)[0], np.arange(8))


def test_fused_kernel_k_exceeds_corpus():
    """Direct kernel call with k > n_docs: real docs first, NEG_INF
    sentinels in the overflow columns (the topk_score contract)."""
    w = jnp.asarray([[1.0, 2.0, 3.0]])
    docs = jnp.asarray([[0, 1, 2]], jnp.int32)
    vals, idx = fused_impact_topk(w, docs, n_docs=3, k=5, block_n=8,
                                  block_w=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx)[0, :3], [2, 1, 0])
    np.testing.assert_allclose(np.asarray(vals)[0, :3], [3.0, 2.0, 1.0])
    assert (np.asarray(vals)[0, 3:] == NEG_INF).all()


def test_fused_kernel_empty_window():
    """W == 0 (no active terms anywhere) must not build an empty grid:
    all scores 0, ids ascending."""
    vals, idx = fused_impact_topk(
        jnp.zeros((2, 0), jnp.float32), jnp.zeros((2, 0), jnp.int32),
        n_docs=16, k=4, block_n=8, block_w=128, interpret=True)
    assert (np.asarray(vals) == 0).all()
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.tile(np.arange(4), (2, 1)))


# ---------------------------------------------------------------------------
# quantized-index parity (in-kernel u4+delta decode)
# ---------------------------------------------------------------------------

def test_fused_quantized_matches_unfused_quantized(graded):
    """Same compressed index on both sides, so the ids must match
    bit-exactly — not merely within quantization tolerance."""
    quant = quantize_index(graded["raw"])
    v_ref, i_ref = quantized_retrieve(graded["q"], quant, K)
    v_f, i_f = fused_quantized_retrieve(graded["q"], quant, K,
                                        block_n=64, block_w=128,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v_f), np.asarray(v_ref),
                               atol=1e-4)
    # and through the dispatcher with autotune-resolved blocks
    v_d, i_d = retrieve(graded["q"], quant, K, method="fused",
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_ref))


def test_fused_quantized_handles_escape_phantoms():
    """Large doc-id gaps round-trip through escape phantoms (code 0):
    the in-kernel decode must advance the cumsum without scoring."""
    n = 2000
    v = np.zeros((n, 2), np.float32)
    i = np.zeros((n, 2), np.int32)
    docs = np.concatenate([np.arange(64), [777, 1901]])
    v[docs, 0] = 1.5
    i[docs, 0] = 3
    from repro.retrieval import SparseRep
    rep = SparseRep(v, i, (v > 0).sum(1).astype(np.int32))
    quant = quantize_index(build_inverted_index(rep, 8))
    assert quant.stats()["phantom_frac"] > 0
    q = SparseRep(np.ones((1, 1), np.float32),
                  np.full((1, 1), 3, np.int32), np.ones(1, np.int32))
    # k covers every positive-scoring doc (66), so the long-jump docs
    # must surface — a dropped escape phantom would shift their cumsum
    # and score the wrong doc ids instead
    v_ref, i_ref = quantized_retrieve(q, quant, 70)
    v_f, i_f = fused_quantized_retrieve(q, quant, 70, block_n=512,
                                        block_w=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_ref))
    pos = set(np.asarray(i_f)[0][np.asarray(v_f)[0] > 0].tolist())
    assert {777, 1901} <= pos


# ---------------------------------------------------------------------------
# property test: fused == impact on random shapes
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(n_docs=st.integers(3, 48), doc_nnz=st.integers(1, 6),
       q_nnz=st.integers(1, 8), k=st.integers(1, 12),
       zero_q=st.booleans(), seed=st.integers(0, 2 ** 16))
def test_fused_vs_impact_property(n_docs, doc_nnz, q_nnz, k, zero_q,
                                  seed):
    """Randomized id parity. Values are multiples of 1/8, so every
    product is a multiple of 1/64 and every partial sum is exactly
    representable in f32 — summation order cannot flip a tie, making
    id equality a hard invariant (duplicates included). Covers empty
    query rows (zero_q) and k >= N (k is clamped identically by both
    paths)."""
    rng = np.random.default_rng(seed)
    vocab = 64
    D = rng.integers(0, 16, size=(n_docs, vocab)).astype(np.float32) / 8
    Q = rng.integers(0, 16, size=(3, vocab)).astype(np.float32) / 8
    if zero_q:
        Q[0] = 0.0
    d = sparsify_topk(jnp.asarray(D), doc_nnz)
    q = sparsify_topk(jnp.asarray(Q), q_nnz)
    index = build_inverted_index(d, vocab)

    v_ref, i_ref = retrieve(q, index, k, method="impact")
    v_f, i_f = fused_retrieve(q, index, k, block_n=16, block_w=128,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(v_f), np.asarray(v_ref))


# ---------------------------------------------------------------------------
# analytic window model
# ---------------------------------------------------------------------------

def test_fused_window_bytes_model():
    assert fused_window_bytes(4, 16, 32) == 4 * 16 * 32 * 8
    assert (fused_window_bytes(4, 16, 32, "u4")
            == 4 * 16 * 32 * 8 + 4 * 16 * 5 * 4)
    with pytest.raises(ValueError, match="variant"):
        fused_window_bytes(1, 1, 1, "f16")


# ---------------------------------------------------------------------------
# multidevice subprocess (CI forces 1/2/4 host devices)
# ---------------------------------------------------------------------------

_FUSED_SCRIPT = textwrap.dedent("""
    import os
    n = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "2"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n}")
    import jax, jax.numpy as jnp, numpy as np
    from repro.data.synthetic import lsr_impact_corpus
    from repro.retrieval import (build_inverted_index, quantize_index,
                                 retrieve, sparsify_topk)

    assert jax.device_count() >= n, jax.devices()
    data = lsr_impact_corpus(n_docs=192, vocab=256, doc_nnz=16,
                             n_queries=4, q_nnz=14, graded=6)
    q = sparsify_topk(jnp.asarray(data["queries"]), 14)
    d = sparsify_topk(jnp.asarray(data["docs"]), 16)
    k = 4
    raw = build_inverted_index(d, 256)
    v_ref, i_ref = retrieve(q, raw, k, method="impact")
    v_f, i_f = retrieve(q, raw, k, method="fused", interpret=True,
                        block_n=64, block_w=128)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v_f), np.asarray(v_ref),
                               atol=1e-4)
    quant = quantize_index(raw)
    v_q, i_q = retrieve(q, quant, k, method="quantized")
    v_fq, i_fq = retrieve(q, quant, k, method="fused", interpret=True)
    np.testing.assert_array_equal(np.asarray(i_fq), np.asarray(i_q))
    print("ALL_FUSED_IMPACT_PASSED")
""")


def test_fused_kernel_multi_device_subprocess():
    """Fused kernel under the Pallas interpreter with forced host
    devices (mirrors test_engine's subprocess pattern — the
    device-count flag never leaks into this process). Device count:
    REPRO_SHARD_TEST_DEVICES (default 2; CI's multidevice job sweeps
    1/2/4)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-c", _FUSED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    assert "ALL_FUSED_IMPACT_PASSED" in proc.stdout
