"""Block-size autotuner for the Sparton Pallas kernels.

The v1 kernels hard-coded ``(8, 128, 128)`` blocks for every shape from
Splade-BERT (V≈30k) to XLM-R (V≈250k). Block choice governs both HBM
traffic and VMEM residency, and the best point moves with the shape:

* total HBM reads of the forward are
  ``|H| * V/block_v  +  |E| * B/block_b``
  (each H tile is re-fetched per vocab block; each E tile per batch
  block), so large-V shapes want the largest ``block_v`` that fits;
* VMEM must hold the double-buffered input tiles, the logit tile, the
  scratch accumulators — and, because the same blocks drive the
  backward, the ``(block_b, block_s, D)`` / ``(block_v, D)`` backward
  scratch accumulators too.

This module enumerates candidates under a VMEM budget, scores them
analytically (``heuristic_blocks``), optionally *times* them
(``autotune_blocks`` — on a TPU the real kernel, elsewhere the Pallas
interpreter on a capped proxy shape), and persists measured winners in
a JSON cache keyed by ``(B, S, D, V, dtype, backend)``.

``get_blocks`` is the cheap entry point used by the kernel wrappers
when no explicit blocks are passed: cache hit, else heuristic — never
a measurement (safe to call under ``jax.jit`` tracing).
"""

from __future__ import annotations

import json
import os
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Blocks = Tuple[int, int, int]  # (block_b, block_s, block_v)
ImpactBlocks = Tuple[int, int]  # (block_n, block_w)

# The three Pallas kernels with independently tunable blocks. One joint
# triple (the legacy scheme) leaves measurable wins on the table at
# large D: the dH kernel's VMEM is dominated by its (bb, bs, D) scratch
# while dE's is (bv, D), so their feasible/optimal regions differ.
KERNELS = ("fwd", "dh", "de")
# Fused impact-scoring kernel variants (kernels/impact_score.py): raw
# f32 windows vs in-kernel u4+delta dequant. Tuned separately from the
# head kernels — different block axes ((block_n, block_w), not a
# (bb, bs, bv) triple) and a different shape key ("_impact" suffix).
IMPACT_VARIANTS = ("f32", "u4")

CACHE_ENV = "SPARTON_AUTOTUNE_CACHE"
DEFAULT_CACHE = os.path.join(
    os.path.expanduser("~"), ".cache", "sparton", "autotune.json"
)
# ~16 MB VMEM per TensorCore; leave headroom for Mosaic's own buffers.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_BB_CHOICES = (1, 2, 4, 8, 16, 32)
_BS_CHOICES = (64, 128, 256, 512)
_BV_CHOICES = (128, 256, 512, 1024, 2048)
_IMPACT_BN_CHOICES = (128, 256, 512, 1024, 2048, 4096)
_IMPACT_BW_CHOICES = (128, 256, 512)

# Smallest enumerable triple — the overflow-*minimizing* fallback when
# no candidate fits the budget (a huge D can make even this overflow,
# but never by more than any other choice would).
MIN_BLOCKS: Blocks = (min(_BB_CHOICES), min(_BS_CHOICES),
                      min(_BV_CHOICES))
MIN_IMPACT_BLOCKS: ImpactBlocks = (min(_IMPACT_BN_CHOICES),
                                   min(_IMPACT_BW_CHOICES))

# One in-memory cache per JSON file: entries from distinct cache paths
# must never bleed into each other's saves.
_caches: Dict[str, Dict[str, dict]] = {}


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def cache_path(path: Optional[str] = None) -> str:
    return path or os.environ.get(CACHE_ENV) or DEFAULT_CACHE


def shape_key(B: int, S: int, D: int, V: int, dtype, backend: str,
              kernel: Optional[str] = None) -> str:
    """Cache key for a shape — optionally extended per kernel.

    ``kernel=None`` is the legacy joint key (one triple for all three
    kernels); ``"fwd"``/``"dh"``/``"de"`` suffixes address per-kernel
    winners. Old cache files only hold joint keys and stay readable:
    per-kernel lookups fall back to the joint entry.
    """
    base = f"B{B}_S{S}_D{D}_V{V}_{jnp.dtype(dtype).name}_{backend}"
    return base if kernel is None else f"{base}_{kernel}"


def impact_shape_key(B: int, Q: int, L: int, N: int, variant: str,
                     backend: str) -> str:
    """Cache key for the fused impact-scoring kernel.

    Its shape space is (batch, query width, window length, corpus
    docs) — disjoint from the head kernels' (B, S, D, V) — and the
    ``_impact`` suffix keeps the two families from ever colliding in
    one cache file. ``variant`` is "f32" (raw windows) or "u4"
    (in-kernel dequant).
    """
    if variant not in IMPACT_VARIANTS:
        raise ValueError(f"unknown impact variant {variant!r}; "
                         f"one of {list(IMPACT_VARIANTS)}")
    return f"B{B}_Q{Q}_L{L}_N{N}_{variant}_{backend}_impact"


def _load(path: str) -> Dict[str, dict]:
    if path not in _caches:
        cache: Dict[str, dict] = {}
        try:
            with open(path) as f:
                cache.update(json.load(f))
        except (OSError, ValueError):
            pass
        _caches[path] = cache
    return _caches[path]


def _save(path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # Read-merge-write: another process (a second tuner on a shared
    # home dir, a parallel CI job) may have persisted winners since our
    # _load — merge them in rather than clobbering the file with our
    # stale view. Our own entries win per-key. Not a lock, but it
    # shrinks the lost-update window to a single key instead of the
    # whole file.
    merged: Dict[str, dict] = {}
    try:
        with open(path) as f:
            merged.update(json.load(f))
    except (OSError, ValueError):
        pass
    merged.update(_caches.get(path, {}))
    _caches[path] = merged
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def clear_cache(path: Optional[str] = None, *, disk: bool = False) -> None:
    """Drop the in-memory caches (and optionally one JSON file)."""
    _caches.clear()
    if disk:
        try:
            os.remove(cache_path(path))
        except OSError:
            pass


# ---------------------------------------------------------------------------
# VMEM model + candidate enumeration
# ---------------------------------------------------------------------------

def _vmem_components(blocks: Blocks, D: int, dtype=jnp.float32
                     ) -> Dict[str, int]:
    """Per-kernel VMEM residency (double-buffered pipelined tiles,
    single-buffered scratch accumulators, in-register logit/one-hot
    tile)."""
    bb, bs, bv = blocks
    in_b = jnp.dtype(dtype).itemsize
    f32 = 4
    tile_bv = bb * bv * f32                      # dy/y/g/out (B, V) tiles
    fwd = (2 * (bb * bs * D * in_b + bv * D * in_b + bv * f32)
           + bb * bs * bv * f32                  # logit tile
           + 2 * 2 * tile_bv                     # y, i outputs
           + 2 * tile_bv)                        # max/argmax scratch
    dh = (2 * (3 * tile_bv + bv * D * in_b)
          + bb * bs * bv * f32                   # one-hot tile
          + bb * bs * D * f32                    # scratch accumulator
          + 2 * bb * bs * D * f32)               # output tile
    de = (2 * (3 * tile_bv + bb * bs * D * in_b)
          + bb * bs * bv * f32
          + bv * D * f32 + bv * f32              # scratch accumulators
          + 2 * (bv * D * f32 + bv * f32))       # output tiles
    return {"fwd": fwd, "dh": dh, "de": de}


def vmem_bytes(blocks: Blocks, D: int, dtype=jnp.float32,
               kernel: Optional[str] = None) -> int:
    """VMEM residency of one kernel, or the worst case over all three
    (``kernel=None`` — the budget a joint triple must satisfy)."""
    comps = _vmem_components(blocks, D, dtype)
    return comps[kernel] if kernel is not None else max(comps.values())


def hbm_traffic_elems(blocks: Blocks, B: int, S: int, D: int,
                      V: int, kernel: Optional[str] = None) -> float:
    """Analytic HBM read volume (elements) of one kernel's grid.

    Uses the *padded* array sizes — the kernels read whole tiles, so a
    block larger than the problem dim pays for the padding. This is
    what makes an oversized block rank strictly worse than a fitting
    one at equal grid counts (instead of winning the size tiebreak).
    Per kernel (from the grid layouts in ``sparton.py``/
    ``sparton_bwd.py``): the forward re-fetches H per vocab block and
    E per batch block; dH re-fetches the three (B, V) operands per
    sequence block and E per (batch, seq) block; dE re-fetches the
    (B, V) operands per sequence block and H per vocab block.
    """
    bb, bs, bv = blocks
    n_b = -(-B // bb)
    n_s = -(-S // bs)
    n_v = -(-V // bv)
    h_padded = float(n_b * bb) * (n_s * bs) * D
    e_padded = float(n_v * bv) * D
    if kernel in (None, "fwd"):
        return h_padded * n_v + e_padded * n_b
    y_padded = float(n_b * bb) * (n_v * bv)      # dy/y/i_max operands
    if kernel == "dh":
        return 3 * y_padded * n_s + e_padded * n_b * n_s
    if kernel == "de":
        return 3 * y_padded * n_s + h_padded * n_v
    raise ValueError(f"unknown kernel {kernel!r}; one of {KERNELS}")


Pinned = Tuple[Optional[int], Optional[int], Optional[int]]


def candidate_blocks(
    B: int, S: int, D: int, V: int,
    *,
    dtype=jnp.float32,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    pinned: Optional[Pinned] = None,
    kernel: Optional[str] = None,
) -> List[Blocks]:
    """All (block_b, block_s, block_v) under the VMEM budget, best first.

    Candidates keep the MXU/VPU alignment rules (block_v a multiple of
    the 128 lane width; block_s a multiple of the sublane tile) and skip
    blocks grossly larger than the padded problem. Sorted by the
    analytic HBM-traffic model, least traffic first. ``pinned``
    components (from a config) are honored exactly — only the free
    components are enumerated, and the VMEM budget is checked on the
    *combined* triple. ``kernel`` scopes both the VMEM residency and
    the traffic model to one kernel (fwd/dh/de); None keeps the legacy
    joint behavior (worst-case VMEM, forward traffic).
    """
    pb, ps, pv = pinned or (None, None, None)
    bbs = (pb,) if pb is not None else _BB_CHOICES
    bss = (ps,) if ps is not None else _BS_CHOICES
    bvs = (pv,) if pv is not None else _BV_CHOICES
    out = []
    for bb in bbs:
        if pb is None and bb > max(8, B):
            continue
        for bs in bss:
            if ps is None and bs > max(128, 2 * S):
                continue
            for bv in bvs:
                if pv is None and bv > max(128, 2 * V):
                    continue
                blocks = (bb, bs, bv)
                if vmem_bytes(blocks, D, dtype, kernel) > vmem_budget:
                    continue
                out.append(blocks)
    out.sort(key=lambda blk: (hbm_traffic_elems(blk, B, S, D, V, kernel),
                              -blk[0] * blk[1] * blk[2]))
    return out


def heuristic_blocks(B: int, S: int, D: int, V: int,
                     *, dtype=jnp.float32,
                     vmem_budget: int = VMEM_BUDGET_BYTES,
                     pinned: Optional[Pinned] = None,
                     kernel: Optional[str] = None) -> Blocks:
    """Best candidate by the analytic model — no measurement.

    With pins, the free components shrink as needed to keep the
    combined triple under the budget; if no free choice fits (the pins
    alone overflow), the smallest free components are used so the
    overflow is at least minimal, not amplified.
    """
    cands = candidate_blocks(B, S, D, V, dtype=dtype,
                             vmem_budget=vmem_budget, pinned=pinned,
                             kernel=kernel)
    if cands:
        return cands[0]
    if pinned and any(p is not None for p in pinned):
        return tuple(p if p is not None else s
                     for p, s in zip(pinned, MIN_BLOCKS))  # type: ignore
    return MIN_BLOCKS


# ---------------------------------------------------------------------------
# lookup + measurement
# ---------------------------------------------------------------------------

def get_blocks(
    B: int, S: int, D: int, V: int,
    *,
    dtype=jnp.float32,
    backend: Optional[str] = None,
    path: Optional[str] = None,
    kernel: Optional[str] = None,
) -> Blocks:
    """Cached winner for the shape, else the analytic heuristic.

    Never measures — cheap enough to call on every kernel invocation
    (including under jit tracing, where it runs once per compilation).
    With ``kernel`` set, the lookup prefers the per-kernel entry and
    falls back to a legacy joint entry (old cache files stay usable),
    then to the kernel-scoped heuristic.
    """
    backend = backend or jax.default_backend()
    cache = _load(cache_path(path))
    hit = cache.get(shape_key(B, S, D, V, dtype, backend, kernel))
    if hit is None and kernel is not None:
        hit = cache.get(shape_key(B, S, D, V, dtype, backend))
    if hit is not None:
        return (hit["block_b"], hit["block_s"], hit["block_v"])
    return heuristic_blocks(B, S, D, V, dtype=dtype, kernel=kernel)


def _measure_shape(B: int, S: int, V: int,
                   interpret: bool) -> Tuple[int, int, int]:
    """Interpret mode executes the grid serially on the host — cap the
    proxy shape so a 250k-vocab tuning run stays seconds, not hours.
    The cache key still records the *real* shape."""
    if not interpret:
        return B, S, V
    return min(B, 8), min(S, 256), min(V, 2048)


def _time_ms(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


def autotune_blocks(
    B: int, S: int, D: int, V: int,
    *,
    dtype=jnp.float32,
    backend: Optional[str] = None,
    softcap: Optional[float] = None,
    interpret: Optional[bool] = None,
    max_candidates: int = 8,
    include_backward: bool = True,
    path: Optional[str] = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> Blocks:
    """Time block candidates for the shape, persist and return the winner.

    On a TPU the real Mosaic kernels are timed at the real shape; on
    CPU/GPU hosts (``interpret`` defaults to True there) the Pallas
    interpreter is timed on a capped proxy shape — a rough but
    deterministic ordering that keeps CI and laptops tune-able.
    """
    from repro.kernels.ops import sparton_head
    from repro.kernels.sparton import sparton_forward

    backend = backend or jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    p = cache_path(path)
    cache = _load(p)
    key = shape_key(B, S, D, V, dtype, backend)
    hit = cache.get(key)
    if hit is not None and hit.get("source") == "measured":
        return (hit["block_b"], hit["block_s"], hit["block_v"])

    cands = candidate_blocks(B, S, D, V, dtype=dtype,
                             vmem_budget=vmem_budget)[:max_candidates]
    if not cands:
        cands = [MIN_BLOCKS]

    mb, ms, mv = _measure_shape(B, S, V, interpret)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    H = jax.random.normal(ks[0], (mb, ms, D), dtype)
    E = jax.random.normal(ks[1], (mv, D), dtype) * 0.2
    bias = jax.random.normal(ks[2], (mv,), jnp.float32) * 0.2
    mask = jnp.ones((mb, ms), jnp.int32)

    best: Tuple[float, Blocks] = (float("inf"), cands[0])
    last_error: Optional[Exception] = None
    for blocks in cands:
        bb, bs, bv = blocks

        def fwd(H, E, bias, mask):
            y, _ = sparton_forward(
                H, E, bias, mask, block_b=bb, block_s=bs, block_v=bv,
                softcap=softcap, interpret=interpret)
            return y

        fn = fwd
        if include_backward:
            def fwd_bwd(H, E, bias, mask, _blk=blocks):
                def loss(H, E, bias):
                    y = sparton_head(
                        H, E, bias, mask, block_b=_blk[0],
                        block_s=_blk[1], block_v=_blk[2],
                        logit_softcap=softcap, interpret=interpret)
                    return jnp.sum(y * y)
                return jax.grad(loss, argnums=(0, 1, 2))(H, E, bias)
            fn = fwd_bwd
        try:
            t = _time_ms(fn, H, E, bias, mask)
        except Exception as e:   # candidate not lowerable on this backend
            last_error = e
            continue
        if t < best[0]:
            best = (t, blocks)

    t, blocks = best
    if t == float("inf"):
        # Every candidate failed to time (e.g. none lowered on this
        # backend): fall back to the heuristic and persist NOTHING, so
        # a later call — possibly in a healthier environment — retries
        # instead of serving a never-validated winner forever. Surface
        # the last error — a systematic kernel bug must not degrade
        # silently into "tuned" blocks.
        warnings.warn(
            f"sparton autotune: all {len(cands)} block candidates "
            f"failed to time for {key}; returning untimed heuristic "
            f"blocks. Last error: {last_error!r}")
        return heuristic_blocks(B, S, D, V, dtype=dtype,
                                vmem_budget=vmem_budget)
    cache[key] = {
        "block_b": blocks[0], "block_s": blocks[1], "block_v": blocks[2],
        "ms": round(t, 3),
        "source": "measured",
        "measured_shape": list(_measure_shape(B, S, V, interpret)) + [D],
        "interpret": bool(interpret),
    }
    _save(p)
    return blocks


def autotune_kernel_blocks(
    B: int, S: int, D: int, V: int,
    *,
    dtype=jnp.float32,
    backend: Optional[str] = None,
    softcap: Optional[float] = None,
    interpret: Optional[bool] = None,
    max_candidates: int = 8,
    path: Optional[str] = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> Dict[str, Blocks]:
    """Time block candidates **per kernel** (fwd, dH, dE), persist and
    return ``{kernel: winner}``.

    The joint tuner (``autotune_blocks``) times fwd+bwd with one triple
    — convenient, but at large D the dH and dE kernels want different
    blocks (their VMEM is dominated by different scratch shapes). This
    tuner times each kernel in isolation on its own candidate set and
    writes one cache entry per kernel (``<shape>_fwd`` etc.); the
    wrappers' per-kernel lookups pick them up, and old joint entries
    remain readable as the fallback.
    """
    from repro.kernels.sparton import sparton_forward
    from repro.kernels.sparton_bwd import (sparton_backward_de,
                                           sparton_backward_dh)

    backend = backend or jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    p = cache_path(path)
    cache = _load(p)
    keys = {kn: shape_key(B, S, D, V, dtype, backend, kn)
            for kn in KERNELS}
    hits = {kn: cache.get(k) for kn, k in keys.items()}
    if all(h is not None and h.get("source") == "measured"
           for h in hits.values()):
        return {kn: (h["block_b"], h["block_s"], h["block_v"])
                for kn, h in hits.items()}

    mb, ms, mv = _measure_shape(B, S, V, interpret)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    H = jax.random.normal(ks[0], (mb, ms, D), dtype)
    E = jax.random.normal(ks[1], (mv, D), dtype) * 0.2
    bias = jax.random.normal(ks[2], (mv,), jnp.float32) * 0.2
    mask = jnp.ones((mb, ms), jnp.int32)
    # one forward at heuristic blocks supplies the backward operands
    fwd_heur = heuristic_blocks(mb, ms, D, mv, dtype=dtype,
                                vmem_budget=vmem_budget, kernel="fwd")
    y, i_max = sparton_forward(
        H, E, bias, mask, block_b=fwd_heur[0], block_s=fwd_heur[1],
        block_v=fwd_heur[2], softcap=softcap, interpret=interpret)
    dy = jax.random.normal(ks[3], y.shape, jnp.float32)

    def fwd_fn(blocks):
        bb, bs, bv = blocks
        return lambda: sparton_forward(
            H, E, bias, mask, block_b=bb, block_s=bs, block_v=bv,
            softcap=softcap, interpret=interpret)

    def dh_fn(blocks):
        bb, bs, bv = blocks
        return lambda: sparton_backward_dh(
            dy, y, i_max, E, ms, block_b=bb, block_s=bs, block_v=bv,
            softcap=softcap, interpret=interpret)

    def de_fn(blocks):
        bb, bs, bv = blocks
        return lambda: sparton_backward_de(
            dy, y, i_max, H, block_b=bb, block_s=bs, block_v=bv,
            softcap=softcap, interpret=interpret)

    builders = {"fwd": fwd_fn, "dh": dh_fn, "de": de_fn}
    winners: Dict[str, Blocks] = {}
    measured_any = False
    for kn in KERNELS:
        hit = hits[kn]
        if hit is not None and hit.get("source") == "measured":
            winners[kn] = (hit["block_b"], hit["block_s"],
                           hit["block_v"])
            continue
        cands = candidate_blocks(B, S, D, V, dtype=dtype,
                                 vmem_budget=vmem_budget,
                                 kernel=kn)[:max_candidates]
        if not cands:
            cands = [MIN_BLOCKS]
        best: Tuple[float, Blocks] = (float("inf"), cands[0])
        last_error: Optional[Exception] = None
        for blocks in cands:
            try:
                t = _time_ms(builders[kn](blocks))
            except Exception as e:  # candidate not lowerable here
                last_error = e
                continue
            if t < best[0]:
                best = (t, blocks)
        t, blocks = best
        if t == float("inf"):
            # same policy as the joint tuner: heuristic, persist
            # nothing, surface the failure
            warnings.warn(
                f"sparton autotune[{kn}]: all {len(cands)} candidates "
                f"failed to time for {keys[kn]}; returning untimed "
                f"heuristic blocks. Last error: {last_error!r}")
            winners[kn] = heuristic_blocks(B, S, D, V, dtype=dtype,
                                           vmem_budget=vmem_budget,
                                           kernel=kn)
            continue
        cache[keys[kn]] = {
            "block_b": blocks[0], "block_s": blocks[1],
            "block_v": blocks[2],
            "ms": round(t, 3),
            "source": "measured",
            "kernel": kn,
            "measured_shape": list(_measure_shape(B, S, V, interpret))
            + [D],
            "interpret": bool(interpret),
        }
        winners[kn] = blocks
        measured_any = True
    if measured_any:
        _save(p)
    return winners


def resolve_blocks(
    B: int, S: int, D: int, V: int, dtype,
    block_b: Optional[int], block_s: Optional[int],
    block_v: Optional[int],
    *,
    kernel: Optional[str] = None,
) -> Blocks:
    """Fill the None components of a user-supplied block triple. Shared
    by every kernel wrapper so forward and backward resolve identically
    for the same inputs.

    Fully unset triples take the cached winner (or heuristic). Partial
    pins are re-enumerated *jointly* with the pins fixed — grafting a
    pin onto a triple tuned without it could blow the VMEM budget —
    which also means they bypass the winner cache on purpose.
    ``kernel`` ("fwd"/"dh"/"de") scopes cache lookup, VMEM model and
    traffic ranking to that kernel; None keeps the joint behavior.
    """
    if block_b is not None and block_s is not None and block_v is not None:
        return (block_b, block_s, block_v)
    if block_b is None and block_s is None and block_v is None:
        return get_blocks(B, S, D, V, dtype=dtype, kernel=kernel)
    return heuristic_blocks(B, S, D, V, dtype=dtype,
                            pinned=(block_b, block_s, block_v),
                            kernel=kernel)


def blocks_for_config(vocab_size: int, d_model: int, batch: int,
                      seq_len: int, dtype: str = "float32",
                      pinned: Optional[Pinned] = None) -> Blocks:
    """Config-level convenience: cached/heuristic blocks for a model
    operating point (used by configs + launch to stop hard-coding).

    Partially pinned configs bypass the winner cache (the cached triple
    was tuned without the pin) and re-enumerate with the pins fixed so
    the combined triple still respects the VMEM budget. No memoization
    beyond the autotune cache itself — a winner persisted later in the
    process must be visible to the next call.
    """
    if pinned is not None and any(p is not None for p in pinned):
        return heuristic_blocks(batch, seq_len, d_model, vocab_size,
                                dtype=jnp.dtype(dtype), pinned=pinned)
    return get_blocks(batch, seq_len, d_model, vocab_size,
                      dtype=jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# fused impact-scoring kernel (kernels/impact_score.py)
# ---------------------------------------------------------------------------

def impact_vmem_bytes(blocks: ImpactBlocks, Q: int, L: int,
                      variant: str = "f32") -> int:
    """VMEM residency of one fused impact grid step.

    The posting window stays resident across every doc tile of a query
    (same block index -> no re-fetch, but Pallas still double-buffers
    it), the per-chunk one-hot tile lives in registers/VMEM during the
    contraction, and the running top-k merge needs the union working
    set. Per variant: "f32" ships two (1, W) arrays (f32 weights + i32
    docs); "u4" ships two (1, Q, L) i32 windows plus five (1, Q, 1)
    per-term columns and decodes (Q, L) weight/doc planes in-kernel.
    """
    bn, bw = blocks
    f32 = 4
    w_lanes = Q * max(L, 1)
    if variant == "f32":
        resident = 2 * 2 * w_lanes * f32           # w + docs, dbl-buf
    else:
        resident = (2 * 2 * w_lanes * f32          # byte + gap windows
                    + 2 * 5 * Q * f32              # per-term columns
                    + 2 * w_lanes * f32)           # decoded w + docs
    onehot = bw * bn * f32                         # chunk one-hot tile
    merge = 4 * bn * f32                           # union vals+ids, x2
    return resident + onehot + bn * f32 + merge


def impact_traffic_proxy(blocks: ImpactBlocks, B: int, Q: int, L: int,
                         N: int) -> float:
    """Analytic cost proxy ranking impact-block candidates.

    HBM traffic is nearly block-independent here (the window loads once
    per query; outputs are (B, k)), so the ranking term is the serial
    merge work: each doc tile pays one union-top-k of ~(k + block_n)
    lanes, and each chunk pays fixed MXU issue overhead — so fewer,
    larger tiles and chunks win until VMEM says stop. The padded tile
    and chunk remainders are charged in full, which is what stops an
    oversized block from winning on tile count alone.
    """
    bn, bw = blocks
    n_tiles = -(-N // bn)
    n_chunks = -(-(Q * max(L, 1)) // bw)
    k_est = 128.0  # merge working set is k+bn lanes; k is unknown here
    merge_cost = n_tiles * (k_est + bn)
    chunk_cost = n_tiles * n_chunks * (64.0 + bw * bn / 8192.0)
    return float(B) * (merge_cost + chunk_cost)


def impact_candidate_blocks(
    B: int, Q: int, L: int, N: int,
    *,
    variant: str = "f32",
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> List[ImpactBlocks]:
    """All (block_n, block_w) under the VMEM budget, best first."""
    out = []
    w_lanes = Q * max(L, 1)
    for bn in _IMPACT_BN_CHOICES:
        if bn > max(128, 2 * N):
            continue
        for bw in _IMPACT_BW_CHOICES:
            if bw > max(128, 2 * w_lanes):
                continue
            blocks = (bn, bw)
            if impact_vmem_bytes(blocks, Q, L, variant) > vmem_budget:
                continue
            out.append(blocks)
    out.sort(key=lambda blk: (impact_traffic_proxy(blk, B, Q, L, N),
                              -blk[0] * blk[1]))
    return out


def heuristic_impact_blocks(B: int, Q: int, L: int, N: int,
                            *, variant: str = "f32",
                            vmem_budget: int = VMEM_BUDGET_BYTES
                            ) -> ImpactBlocks:
    """Best impact candidate by the analytic model — no measurement."""
    cands = impact_candidate_blocks(B, Q, L, N, variant=variant,
                                    vmem_budget=vmem_budget)
    return cands[0] if cands else MIN_IMPACT_BLOCKS


def get_impact_blocks(
    B: int, Q: int, L: int, N: int,
    *,
    variant: str = "f32",
    backend: Optional[str] = None,
    path: Optional[str] = None,
) -> ImpactBlocks:
    """Cached impact-kernel winner for the shape, else the heuristic.

    Same contract as ``get_blocks``: never measures, safe under jit
    tracing. There is no joint-key fallback — the ``_impact`` family
    is new, so a miss goes straight to the heuristic.
    """
    backend = backend or jax.default_backend()
    cache = _load(cache_path(path))
    hit = cache.get(impact_shape_key(B, Q, L, N, variant, backend))
    if hit is not None:
        return (hit["block_n"], hit["block_w"])
    return heuristic_impact_blocks(B, Q, L, N, variant=variant)


def resolve_impact_blocks(
    B: int, Q: int, L: int, N: int,
    block_n: Optional[int], block_w: Optional[int],
    *,
    variant: str = "f32",
) -> ImpactBlocks:
    """Fill the None components of a (block_n, block_w) pair — the
    impact-kernel analogue of ``resolve_blocks``. Partial pins are
    re-enumerated with the pin fixed (bypassing the winner cache, which
    was tuned without it)."""
    if block_n is not None and block_w is not None:
        return (block_n, block_w)
    if block_n is None and block_w is None:
        return get_impact_blocks(B, Q, L, N, variant=variant)
    cands = [blk for blk in impact_candidate_blocks(B, Q, L, N,
                                                    variant=variant)
             if (block_n is None or blk[0] == block_n)
             and (block_w is None or blk[1] == block_w)]
    if cands:
        return cands[0]
    return (block_n or MIN_IMPACT_BLOCKS[0],
            block_w or MIN_IMPACT_BLOCKS[1])


def autotune_impact_blocks(
    B: int, Q: int, L: int, N: int,
    *,
    variant: str = "f32",
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    max_candidates: int = 6,
    k: int = 100,
    path: Optional[str] = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> ImpactBlocks:
    """Time impact-block candidates, persist and return the winner.

    Mirrors ``autotune_blocks``: real kernel at the real shape on a
    TPU, Pallas interpreter on a capped proxy shape elsewhere (the key
    still records the real shape), and the all-candidates-failed path
    returns the untimed heuristic without persisting anything.
    """
    from repro.kernels.impact_score import (fused_impact_topk,
                                            fused_quantized_topk)

    backend = backend or jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    p = cache_path(path)
    cache = _load(p)
    key = impact_shape_key(B, Q, L, N, variant, backend)
    hit = cache.get(key)
    if hit is not None and hit.get("source") == "measured":
        return (hit["block_n"], hit["block_w"])

    cands = impact_candidate_blocks(B, Q, L, N, variant=variant,
                                    vmem_budget=vmem_budget
                                    )[:max_candidates]
    if not cands:
        cands = [MIN_IMPACT_BLOCKS]

    mb, mq, ml, mn = ((min(B, 4), min(Q, 16), min(L, 256),
                       min(N, 4096)) if interpret else (B, Q, L, N))
    rng = np.random.default_rng(0)
    if variant == "f32":
        w = jnp.asarray(rng.uniform(0, 2, (mb, mq * ml)), jnp.float32)
        d = jnp.asarray(rng.integers(0, mn, (mb, mq * ml)), jnp.int32)

        def run(blocks):
            bn, bw = blocks
            return lambda: fused_impact_topk(
                w, d, n_docs=mn, k=min(k, mn), block_n=bn, block_w=bw,
                interpret=interpret)
    else:
        byte = jnp.asarray(rng.integers(0, 256, (mb, mq, ml)), jnp.int32)
        gap = jnp.asarray(rng.integers(0, 3, (mb, mq, ml)), jnp.int32)
        starts = jnp.asarray(rng.integers(0, 2, (mb, mq)), jnp.int32)
        lens = jnp.full((mb, mq), ml, jnp.int32)
        qv = jnp.asarray(rng.uniform(0.1, 2, (mb, mq)), jnp.float32)
        lo = jnp.zeros((mb, mq), jnp.float32)
        step = jnp.full((mb, mq), 0.1, jnp.float32)

        def run(blocks):
            bn, bw = blocks
            return lambda: fused_quantized_topk(
                byte, gap, starts, lens, qv, lo, step, n_docs=mn,
                k=min(k, mn), block_n=bn, block_w=bw,
                interpret=interpret)

    best: Tuple[float, ImpactBlocks] = (float("inf"), cands[0])
    last_error: Optional[Exception] = None
    for blocks in cands:
        try:
            t = _time_ms(run(blocks))
        except Exception as e:   # candidate not lowerable here
            last_error = e
            continue
        if t < best[0]:
            best = (t, blocks)
    t, blocks = best
    if t == float("inf"):
        warnings.warn(
            f"sparton autotune[impact/{variant}]: all {len(cands)} "
            f"candidates failed to time for {key}; returning untimed "
            f"heuristic blocks. Last error: {last_error!r}")
        return heuristic_impact_blocks(B, Q, L, N, variant=variant,
                                       vmem_budget=vmem_budget)
    cache[key] = {
        "block_n": blocks[0], "block_w": blocks[1],
        "ms": round(t, 3),
        "source": "measured",
        "kernel": "impact",
        "variant": variant,
        "measured_shape": [mb, mq, ml, mn],
        "interpret": bool(interpret),
    }
    _save(p)
    return blocks
