"""Learning-rate schedules (step -> lr), pure jnp so they jit."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, total_steps: int, *,
                    final_fraction: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return peak_lr * (final_fraction + (1 - final_fraction) * cos)
    return fn


def linear_warmup_cosine(peak_lr: float, warmup_steps: int,
                         total_steps: int, *, final_fraction: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32) + 1.0  # step 0 must not have lr=0
        warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) /
                     jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_fraction
                         + (1 - final_fraction) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn


def linear_warmup_linear_decay(peak_lr: float, warmup_steps: int,
                               total_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
        decay = peak_lr * jnp.clip(
            (total_steps - s) / jnp.maximum(total_steps - warmup_steps, 1),
            0.0, 1.0)
        return jnp.where(s < warmup_steps, warm, decay)
    return fn
