"""Loss functions: InfoNCE, FLOPS, MarginMSE sanity + properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.losses.contrastive import (flops_regularizer,
                                      gathered_infonce,
                                      infonce_from_scores, infonce_loss,
                                      l1_regularizer, margin_mse_loss,
                                      splade_loss)


def test_infonce_prefers_aligned_pairs():
    # orthogonal one-hot reps: perfect alignment -> low loss
    q = jnp.eye(4, 16)
    d_good = jnp.eye(4, 16) * 10
    d_bad = jnp.roll(jnp.eye(4, 16), 1, axis=0) * 10
    assert float(infonce_loss(q, d_good)) < float(infonce_loss(q, d_bad))


def test_infonce_matches_from_scores():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    q = jax.random.normal(ks[0], (6, 32))
    d = jax.random.normal(ks[1], (6, 32))
    scores = jnp.einsum("qv,dv->qd", q, d)
    np.testing.assert_allclose(float(infonce_loss(q, d)),
                               float(infonce_from_scores(scores)),
                               atol=1e-6)


def test_flops_regularizer_prefers_sparse():
    dense = jnp.ones((8, 64))
    sparse = jnp.zeros((8, 64)).at[:, 0].set(8.0)  # same L1 per example
    assert float(flops_regularizer(sparse)) > 0
    assert float(flops_regularizer(dense)) < float(
        flops_regularizer(sparse) * 64)
    # uniform mass over dims beats concentrated mass for FLOPS
    spread = jnp.full((8, 64), 0.125)
    assert float(flops_regularizer(spread)) < float(
        flops_regularizer(sparse))


def test_margin_mse_zero_when_matching():
    q = jnp.ones((4, 8))
    dp = jnp.ones((4, 8)) * 2
    dn = jnp.ones((4, 8))
    margin = jnp.full((4,), float(jnp.sum(q[0] * (dp[0] - dn[0]))))
    assert float(margin_mse_loss(q, dp, dn, margin)) < 1e-9


def test_splade_loss_composition():
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    q = jax.nn.relu(jax.random.normal(ks[0], (4, 32)))
    d = jax.nn.relu(jax.random.normal(ks[1], (4, 32)))
    base = float(infonce_loss(q, d))
    full = float(splade_loss(q, d, lambda_q=1.0, lambda_d=1.0))
    assert full > base  # regularizers add


def test_gathered_infonce_no_axes_matches_local():
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    q = jax.random.normal(ks[0], (6, 32))
    d = jax.random.normal(ks[1], (6, 32))
    np.testing.assert_allclose(float(gathered_infonce(q, d)),
                               float(infonce_loss(q, d)), atol=1e-6)


def test_gathered_infonce_single_device_axis_matches_local():
    """Under a size-1 shard_map data axis the gathered negatives are
    exactly the local batch — loss must equal plain infonce."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    q = jax.random.normal(ks[0], (8, 16))
    d = jax.random.normal(ks[1], (8, 16))
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    fn = shard_map(
        lambda a, b: gathered_infonce(a, b, axis_names=("data",)),
        mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P(),
        check_vma=False)
    np.testing.assert_allclose(float(fn(q, d)),
                               float(infonce_loss(q, d)), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_infonce_nonnegative_lower_bound(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (5, 16))
    d = jax.random.normal(ks[1], (5, 16))
    # cross-entropy over 5 classes is >= 0 and finite
    l = float(infonce_loss(q, d))
    assert np.isfinite(l) and l >= 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(0.1, 10.0))
def test_property_flops_scale_quadratic(seed, scale):
    y = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed), (6, 24)))
    r1 = float(flops_regularizer(y))
    r2 = float(flops_regularizer(y * scale))
    np.testing.assert_allclose(r2, r1 * scale ** 2, rtol=1e-4)
