"""Retrieval scoring — impact, pruned, quantized, sharded,
streaming-kernel, and dense paths behind one ``retrieve()``
dispatcher.

Dispatch table (``method=``):

    method       queries            corpus             scoring
    ---------    ---------------    ---------------    -------------
    "impact"     SparseRep          InvertedIndex      exact segment-
                                                       sums into (B, N)
    "pruned"     SparseRep          InvertedIndex      two-tier MaxScore:
                                    (+ term_ubs and    upper-bound pass
                                    forward rows)      -> exact rescore
                                                       of candidates
                                                       (engine/pruning)
    "quantized"  SparseRep          QuantizedIndex     on-the-fly
                                                       dequantized
                                                       segment-sums
                                                       (engine/quantize)
    "sharded"    SparseRep          ShardedIndex       per-shard impact
                                                       + cross-shard
                                                       top-k merge
                                                       (engine/
                                                       sharded_index)
    "term_        SparseRep         TermShardedIndex   per-shard PARTIAL
     sharded"                                          sums over vocab
                                                       ranges, psum/
                                                       all-reduce, one
                                                       global top-k
                                                       (engine/
                                                       term_sharded)
    "streaming"  dense or rep       dense (N, V)       fused Pallas
                                                       running top-k
    "dense"      dense or rep       dense (N, V)       (B, N) einsum
                                                       + lax.top_k
    "auto"       resolved from the corpus type:
                 * QuantizedIndex              -> "quantized"
                 * ShardedIndex                -> "sharded"
                 * TermShardedIndex            -> "term_sharded"
                 * InvertedIndex with upper bounds AND forward rows
                   (an engine build)           -> "pruned"
                 * any other InvertedIndex     -> "impact"
                 * dense matrix: "streaming" for corpora >=
                   AUTO_STREAMING_N rows, "dense" below that

Which *sharding axis* to build in the first place is the upstream
choice: ``engine.term_sharded.choose_shard_axis`` keys it on the
posting-array bytes vs the per-device HBM budget — doc sharding
replicates the O(V) term directory per shard and merges cheap
(all_gather of k winners), term sharding splits the posting arrays
exactly (the |V|~250k multilingual regime) at the cost of an
all-reduce over (B, N) partials.

All paths return ``(vals (B, k) f32, idx (B, k) i32)`` with identical
ids (scores within fp/quantization tolerance) for equivalent inputs —
the parity tests in ``tests/test_retrieval.py`` and
``tests/test_engine.py`` pin that down. ``pruned`` is id-identical to
``impact`` at the default safe margin (0.0) with a sufficient
candidate budget; ``prune_margin`` > 0 trades recall for speed.

The impact path is the sparse-native one: per query row it gathers the
posting lists of the query's active terms (padded to the index's
``max_postings`` static width) and reduces them with
``sparse/segment.py`` segment-sums — ``scores[d] = sum_t q[t] *
impact[t, d]`` — exactly the inverted-index formulation GPUSparse
serves LSR with. Work per query is ``O(Q * max_postings)``; the
padding cost is the usual TPU trade of ragged gathers for one static
dense gather + masked reduce.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.topk_score import topk_score
from repro.retrieval.index import InvertedIndex
from repro.retrieval.sparse_rep import SparseRep
from repro.sparse.segment import segment_sum

Array = jax.Array
Queries = Union[Array, SparseRep]
Corpus = Union[Array, InvertedIndex]

METHODS = ("auto", "impact", "pruned", "quantized", "sharded",
           "term_sharded", "streaming", "dense")
# methods that need an index-shaped corpus (not a dense matrix)
_INDEX_METHODS = ("impact", "pruned", "quantized", "sharded",
                  "term_sharded")
# corpora at or above this many rows route "auto" to the streaming
# kernel (the (B, N) score matrix stops being a rounding error)
AUTO_STREAMING_N = 16384


# ---------------------------------------------------------------------------
# impact scoring (inverted index)
# ---------------------------------------------------------------------------

def impact_scores(queries: SparseRep, index: InvertedIndex) -> Array:
    """Dense ``(B, n_docs)`` impact scores — no (N, V) matrix anywhere.

    Padded query slots (value 0) and posting-list padding both
    contribute exactly 0 to the segment-sums, so no masking state
    leaks into the scores.
    """
    l_max = index.max_postings
    p_total = index.postings_doc.shape[0]
    lane = jnp.arange(l_max, dtype=jnp.int32)

    def one(qv: Array, qi: Array) -> Array:
        starts = index.term_starts[qi]                     # (Q,)
        lens = index.term_lens[qi]                         # (Q,)
        pos = starts[:, None] + lane[None, :]              # (Q, Lmax)
        valid = (lane[None, :] < lens[:, None]) & (qv > 0)[:, None]
        pos = jnp.clip(pos, 0, p_total - 1)
        docs = jnp.where(valid, index.postings_doc[pos], 0)
        w = jnp.where(valid, index.postings_val[pos], 0.0) * qv[:, None]
        return segment_sum(w.ravel(), docs.ravel(), index.n_docs)

    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    return jax.vmap(one)(qv, qi)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def _dense_queries(queries: Queries, vocab_size: int) -> Array:
    if isinstance(queries, SparseRep):
        return queries.to_dense(vocab_size)
    return queries


def _resolve_method(method: str, corpus: Corpus) -> str:
    from repro.retrieval.engine.quantize import QuantizedIndex
    from repro.retrieval.engine.sharded_index import ShardedIndex
    from repro.retrieval.engine.term_sharded import TermShardedIndex

    if method not in METHODS:
        raise ValueError(f"unknown retrieval method {method!r}; "
                         f"one of {list(METHODS)}")
    if method != "auto":
        return method
    if isinstance(corpus, QuantizedIndex):
        return "quantized"
    if isinstance(corpus, ShardedIndex):
        return "sharded"
    if isinstance(corpus, TermShardedIndex):
        return "term_sharded"
    if isinstance(corpus, InvertedIndex):
        # an engine build (upper bounds + forward rows) can serve the
        # two-tier pruned path; a bare PR-3 index only the exact one
        if corpus.has_upper_bounds and corpus.has_forward:
            return "pruned"
        return "impact"
    return "streaming" if corpus.shape[0] >= AUTO_STREAMING_N else "dense"


@functools.partial(jax.jit, static_argnames=("k",))
def _dense_retrieve(q: Array, C: Array, k: int) -> Tuple[Array, Array]:
    scores = jnp.einsum("bv,nv->bn", q.astype(jnp.float32),
                        C.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def _impact_retrieve(queries: SparseRep, index: InvertedIndex, k: int
                     ) -> Tuple[Array, Array]:
    scores = impact_scores(queries, index)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def retrieve(
    queries: Queries,           # (B, V) dense or SparseRep
    corpus: Corpus,             # (N, V) dense matrix or an index
    k: int = 10,
    *,
    method: str = "auto",
    interpret: Optional[bool] = None,
    block_b: int = 8,
    block_n: int = 1024,
    prune_margin: float = 0.0,
    candidates: Optional[int] = None,
    mesh=None,
    axis_name: Optional[str] = None,
) -> Tuple[Array, Array]:
    """Top-k retrieval via the method table in the module docstring.

    ``k`` is clamped to the corpus size so every path returns the same
    ``(B, min(k, N))`` shape. ``interpret`` only affects the streaming
    kernel (None = auto: Pallas interpreter off-TPU);
    ``prune_margin``/``candidates`` only the pruned path
    (``engine.pruning``) and, for margins > 0, the term-sharded
    two-tier composition; ``mesh``/``axis_name`` only the sharded
    paths (None = single-device vmap over shards).
    """
    method = _resolve_method(method, corpus)

    if method in _INDEX_METHODS:
        from repro.retrieval.engine.quantize import (QuantizedIndex,
                                                     quantized_retrieve)
        from repro.retrieval.engine.sharded_index import (ShardedIndex,
                                                          sharded_retrieve)

        if not isinstance(queries, SparseRep):
            raise ValueError(
                f"method={method!r} needs SparseRep queries — sparsify "
                "with retrieval.sparse_rep.sparsify_topk/threshold "
                "(an explicit budget, not a silent one)")
        if method == "quantized":
            if not isinstance(corpus, QuantizedIndex):
                raise ValueError(
                    "method='quantized' needs a QuantizedIndex corpus "
                    "— compress one with engine.quantize.quantize_index")
            return quantized_retrieve(queries, corpus, k)
        if method == "sharded":
            if not isinstance(corpus, ShardedIndex):
                raise ValueError(
                    "method='sharded' needs a ShardedIndex corpus — "
                    "build one with engine.sharded_index.shard_index")
            return sharded_retrieve(queries, corpus, k, mesh=mesh,
                                    axis_name=axis_name)
        if method == "term_sharded":
            from repro.retrieval.engine.term_sharded import (
                TermShardedIndex, term_sharded_retrieve)

            if not isinstance(corpus, TermShardedIndex):
                raise ValueError(
                    "method='term_sharded' needs a TermShardedIndex "
                    "corpus — build one with "
                    "engine.term_sharded.term_shard_index")
            # margin 0 routes to the exact psum path (identical ids,
            # no candidate budget to size); > 0 opts into the
            # two-tier composition and requires forward rows
            return term_sharded_retrieve(
                queries, corpus, k, mesh=mesh, axis_name=axis_name,
                prune_margin=prune_margin if prune_margin > 0 else None,
                candidates=candidates)
        if not isinstance(corpus, InvertedIndex):
            raise ValueError(
                f"method={method!r} needs an InvertedIndex corpus — "
                "build one with retrieval.index.build_inverted_index")
        if method == "pruned":
            from repro.retrieval.engine.pruning import pruned_retrieve

            return pruned_retrieve(queries, corpus, k,
                                   prune_margin=prune_margin,
                                   candidates=candidates)
        return _impact_retrieve(queries, corpus, min(k, corpus.n_docs))

    if isinstance(corpus, InvertedIndex) or not hasattr(corpus, "shape"):
        raise ValueError(
            f"method={method!r} needs a dense (N, V) corpus matrix; "
            f"got {type(corpus).__name__} (use an index method or "
            "'auto')")
    n_docs, vocab = corpus.shape
    q = _dense_queries(queries, vocab)
    k = min(k, n_docs)

    if method == "dense":
        return _dense_retrieve(q, corpus, k)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return topk_score(q, corpus, k=k, block_b=block_b,
                      block_n=block_n, interpret=interpret)
