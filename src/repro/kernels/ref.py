"""Pure-jnp oracles for the Pallas kernels in this package.

These are deliberately naive (they materialize everything) — they exist
only as the ground truth for the kernel allclose sweeps in
``tests/test_kernels_sparton.py`` and ``tests/test_kernels_topk.py``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels._common import NEG_INF, bwd_factor


def _raw_logits(H, E, b, mask, softcap):
    logits = jnp.einsum(
        "bsd,vd->bsv", H, E, preferred_element_type=jnp.float32
    )
    if b is not None:
        logits = logits + b.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        logits = jnp.where(mask.astype(bool)[:, :, None], logits, NEG_INF)
    return logits


def sparton_forward_ref(
    H: jax.Array,
    E: jax.Array,
    b: Optional[jax.Array],
    mask: Optional[jax.Array],
    softcap: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Oracle for kernels.sparton.sparton_forward. Returns (y, i_max)."""
    logits = _raw_logits(H, E, b, mask, softcap)
    raw_max = jnp.max(logits, axis=1)
    i_max = jnp.argmax(logits, axis=1).astype(jnp.int32)
    y = jnp.log1p(jnp.maximum(raw_max, 0.0))
    return y, i_max


def sparton_backward_ref(
    g: jax.Array,       # (B, V) — already includes the f' factor
    i_max: jax.Array,   # (B, V)
    H: jax.Array,       # (B, S, D)
    E: jax.Array,       # (V, D)
) -> Tuple[jax.Array, jax.Array]:
    """Oracle for kernels.sparton_bwd.sparton_backward."""
    B, S, D = H.shape
    V = E.shape[0]
    onehot = jax.nn.one_hot(i_max, S, dtype=jnp.float32)   # (B, V, S)
    w = onehot * g.astype(jnp.float32)[..., None]          # (B, V, S)
    dH = jnp.einsum("bvs,vd->bsd", w, E.astype(jnp.float32))
    dE = jnp.einsum("bvs,bsd->vd", w, H.astype(jnp.float32))
    return dH, dE


def sparton_backward_fused_ref(
    dy: jax.Array,      # (B, V) — raw upstream cotangent
    y: jax.Array,       # (B, V) — stored post-activation
    i_max: jax.Array,   # (B, V)
    H: jax.Array,       # (B, S, D)
    E: jax.Array,       # (V, D)
    softcap: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the v2 fused backward: (dH, dE, db) from (dy, y)."""
    g = bwd_factor(y.astype(jnp.float32), dy, softcap)
    dH, dE = sparton_backward_ref(g, i_max, H, E)
    return dH, dE, jnp.sum(g, axis=0)


def topk_score_ref(
    q: jax.Array,       # (D,) or (B, D)
    C: jax.Array,       # (N, D) candidate matrix
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Oracle for kernels.topk_score: scores + indices of top-k by dot."""
    q2 = q if q.ndim == 2 else q[None]
    scores = jnp.einsum(
        "bd,nd->bn", q2, C, preferred_element_type=jnp.float32
    )
    vals, idx = jax.lax.top_k(scores, k)
    if q.ndim == 1:
        return vals[0], idx[0].astype(jnp.int32)
    return vals, idx.astype(jnp.int32)
